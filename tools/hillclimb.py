import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Perf-iteration driver: lower one cell with overrides, print the three
roofline terms + per-kind collective breakdown + memory analysis.

Usage:
  PYTHONPATH=src python tools/hillclimb.py --arch qwen3-0.6b --shape train_4k \
      [--profile replicated] [--remat dots] [--kv-dtype int8] \
      [--analysis unroll|extrapolate|scan] [--tag name]

Appends a JSON line to experiments/perf/<arch>__<shape>.jsonl.
"""
import argparse
import json
import time

import jax

from repro.configs import get_config
from repro.configs.shapes import SHAPES
from repro.distributed.sharding import use_mesh
from repro.launch.dryrun import parse_collectives, _analyse_compiled
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import plan_cell
from repro.train.train_step import TrainConfig

PEAK, HBM, LINK = 197e12, 819e9, 50e9


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--profile", default="fsdp",
                    choices=("fsdp", "replicated", "dp", "dp_zero3"))
    ap.add_argument("--remat", default="full",
                    choices=("none", "full", "dots"))
    ap.add_argument("--kv-dtype", default="bfloat16",
                    choices=("bfloat16", "int8"))
    ap.add_argument("--analysis", default="unroll",
                    choices=("unroll", "extrapolate", "scan"))
    ap.add_argument("--no-donate", action="store_true")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    shape = SHAPES[args.shape]
    mesh = make_production_mesh(multi_pod=False)
    tc = TrainConfig(remat=args.remat, sharding_profile=args.profile,
                     unroll=args.analysis == "unroll")
    rec = {"tag": args.tag or f"{args.profile}/{args.remat}/{args.kv_dtype}",
           "arch": args.arch, "shape": args.shape,
           "profile": args.profile, "remat": args.remat,
           "kv_dtype": args.kv_dtype, "analysis": args.analysis}
    t0 = time.time()
    with use_mesh(mesh):
        if args.analysis == "extrapolate":
            from repro.launch.extrapolate import extrapolate_cell
            est = extrapolate_cell(
                cfg, shape, mesh, parse_collectives,
                train_cfg=TrainConfig(remat=args.remat,
                                      sharding_profile=args.profile),
                kv_dtype=args.kv_dtype)
            flops, byts = est["flops"], est["bytes accessed"]
            coll = est["coll_operand"]
            kinds = {k: v for k, v in est.items() if k.startswith("coll_")}
            # memory analysis still needs a scanned compile
            plan = plan_cell(cfg, shape, mesh,
                             train_cfg=TrainConfig(
                                 remat=args.remat,
                                 sharding_profile=args.profile),
                             kv_dtype=args.kv_dtype)
            compiled = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                               out_shardings=plan.out_shardings,
                               donate_argnums=() if args.no_donate
                               else plan.donate).lower(*plan.args).compile()
            mem = _analyse_compiled(compiled).get("memory", {})
        else:
            plan = plan_cell(cfg, shape, mesh, train_cfg=tc,
                             kv_dtype=args.kv_dtype)
            compiled = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                               out_shardings=plan.out_shardings,
                               donate_argnums=() if args.no_donate
                               else plan.donate).lower(*plan.args).compile()
            a = _analyse_compiled(compiled)
            flops = a.get("cost", {}).get("flops", 0.0)
            byts = a.get("cost", {}).get("bytes accessed", 0.0)
            coll = a["collectives"]["total_operand_bytes"]
            kinds = {k: v["operand_bytes"] for k, v in
                     a["collectives"].items() if isinstance(v, dict)}
            mem = a.get("memory", {})
    rec.update({
        "seconds": round(time.time() - t0, 1),
        "flops": flops, "bytes": byts, "coll_operand_bytes": coll,
        "coll_kinds": kinds,
        "t_compute": flops / PEAK, "t_memory": byts / HBM,
        "t_collective": coll / LINK,
        "mem_args": mem.get("argument_size_in_bytes", 0),
        "mem_temp": mem.get("temp_size_in_bytes", 0),
        "mem_out": mem.get("output_size_in_bytes", 0),
    })
    os.makedirs("experiments/perf", exist_ok=True)
    path = (f"experiments/perf/{args.arch.replace('.', '_')}"
            f"__{args.shape}.jsonl")
    with open(path, "a") as f:
        f.write(json.dumps(rec) + "\n")
    print(f"[{rec['tag']}] compute {rec['t_compute']:.3f}s | "
          f"memory {rec['t_memory']:.3f}s | "
          f"collective {rec['t_collective']:.3f}s | "
          f"temp {rec['mem_temp'] / 1e9:.1f}GB args "
          f"{rec['mem_args'] / 1e9:.1f}GB  ({rec['seconds']}s)")
    for k, v in sorted(rec["coll_kinds"].items(), key=lambda x: -x[1]):
        if v:
            print(f"    {k}: {v:.3e} B")


if __name__ == "__main__":
    main()
