"""repro-lint CLI: ``python -m tools.check`` from the repo root.

Layers:

* default / ``--lint``  — AST rules R1–R6 + R8 and the R7 import-graph
  dead-code report, gated against the committed baseline
  ``tools/check_allowlist.json`` (new finding → fail; stale baseline
  entry → fail; the file only ratchets down).
* ``--audit``           — jaxpr contract audit: trace every valid
  rule × backend × layer-kind matrix cell abstractly and check the
  dataflow contracts (uint8 operands, no float64).  Slower (imports
  jax and traces ~50 cells); CI runs it via the ``static_audit``
  benchmark too, which records the primitive-count fingerprint.
* ``--docs``            — doc-lint rules D1/D2: every fenced
  ```` ```python ```` snippet in README.md/docs/ executes clean from
  the repo root, and every relative markdown link resolves.  No
  allowlist — broken docs are fixed, not baselined.
* ``--all``             — all three layers (the CI gate).

``--explain R3`` prints a rule's rationale; ``--update-allowlist``
regenerates the baseline from the current findings, keeping existing
justifications.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT / "src") not in sys.path:  # plain `python -m tools.check`
    sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.analysis import (  # noqa: E402
    ALL_RULES,
    DOC_RULE_EXPLAIN,
    RULE_EXPLAIN,
    apply_allowlist,
    load_allowlist,
    render_allowlist,
    run_doclint,
    run_lint,
)
from repro.analysis.doclint import doc_files  # noqa: E402


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="tools.check",
        description="static-analysis gate for the repo's hardware contracts",
    )
    ap.add_argument("--all", action="store_true", help="run every layer (lint + audit + docs)")
    ap.add_argument("--lint", action="store_true", help="run the lint layer (default)")
    ap.add_argument("--audit", action="store_true", help="run the jaxpr contract audit layer")
    ap.add_argument("--docs", action="store_true", help="run the doc-lint layer (snippets + links)")
    ap.add_argument("--rules", nargs="*", default=[], metavar="R", help="restrict lint to rules")
    ap.add_argument("--explain", metavar="RULE", help="print a rule's rationale and exit")
    ap.add_argument("--update-allowlist", action="store_true", help="regenerate the baseline")
    ap.add_argument("--root", type=Path, default=REPO_ROOT, help="tree to scan (default: repo)")
    ap.add_argument("--allowlist", type=Path, default=REPO_ROOT / "tools" / "check_allowlist.json")
    args = ap.parse_args(argv)

    if args.explain:
        text = RULE_EXPLAIN.get(args.explain) or DOC_RULE_EXPLAIN.get(args.explain)
        if text is None:
            known = ALL_RULES + tuple(DOC_RULE_EXPLAIN)
            print(f"unknown rule {args.explain!r}; have {known}")
            return 2
        print(text)
        return 0

    run_lint_layer = args.lint or args.all or not (args.audit or args.docs)
    run_audit_layer = args.audit or args.all
    run_docs_layer = args.docs or args.all
    rc = 0

    if run_lint_layer:
        findings = run_lint(args.root, args.rules)
        if args.update_allowlist:
            previous = load_allowlist(args.allowlist)
            args.allowlist.write_text(render_allowlist(findings, previous))
            print(f"wrote {args.allowlist} ({len(findings)} baselined findings)")
            return 0
        allow = load_allowlist(args.allowlist)
        new, stale = apply_allowlist(findings, allow)
        for f in new:
            print(f.render())
        for rule, key in stale:
            print(f"STALE allowlist entry {rule} {key} — violation fixed; remove the entry")
        n_base = len(findings) - len(new)
        if new or stale:
            print(f"lint: {len(new)} new finding(s), {len(stale)} stale, {n_base} baselined — FAIL")
            rc = 1
        else:
            print(f"lint: clean ({n_base} baselined finding(s))")

    if run_audit_layer:
        from repro.analysis.jaxpr_audit import run_audit

        report = run_audit()
        bad = [c for c in report["cells"] if c["violations"]]
        for c in bad:
            for v in c["violations"]:
                print(f"AUDIT {c['rule']}×{c['backend']}×{c['kind']}: {v}")
        status = " — FAIL" if bad else ""
        print(f"audit: {len(report['cells'])} cells traced, {len(bad)} violating{status}")
        if bad:
            rc = 1

    if run_docs_layer:
        doc_findings = run_doclint(args.root)
        for f in doc_findings:
            print(f.render())
        if doc_findings:
            print(f"docs: {len(doc_findings)} finding(s) — FAIL")
            rc = 1
        else:
            print(f"docs: clean ({len(doc_files(args.root))} file(s) checked)")

    return rc


if __name__ == "__main__":
    sys.exit(main())
