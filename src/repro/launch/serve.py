"""Serving launcher: ``python -m repro.launch.serve --arch <id> [...]``.

Brings up the slot-based continuous-batching server on a (smoke) model,
submits a synthetic request load, and reports latency/throughput.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.models import transformer
from repro.serve import Request, ServeConfig, Server


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-0.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--context", type=int, default=256)
    ap.add_argument("--kv-dtype", choices=("bfloat16", "int8"),
                    default="bfloat16")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    scfg = ServeConfig(max_tokens=args.context, batch=args.slots,
                       kv_dtype=args.kv_dtype,
                       temperature=args.temperature)
    server = Server(params, cfg, scfg)

    key = jax.random.PRNGKey(1)
    for i in range(args.requests):
        key, sub = jax.random.split(key)
        plen = int(jax.random.randint(sub, (), 4, 16))
        prompt = [int(t) for t in
                  jax.random.randint(sub, (plen,), 0, cfg.vocab_size)]
        server.submit(Request(uid=i, prompt=prompt, max_new=args.max_new))

    t0 = time.time()
    done = server.run(max_steps=args.max_new * args.requests + 64)
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in done)
    print(f"served {len(done)}/{args.requests} requests, {n_tok} tokens "
          f"in {dt:.1f}s ({n_tok / max(dt, 1e-9):.1f} tok/s, "
          f"kv={args.kv_dtype})")
    for r in done[:3]:
        print(f"  req {r.uid}: {len(r.prompt)} prompt → {r.out[:8]}…")


if __name__ == "__main__":
    main()
