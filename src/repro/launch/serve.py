"""Serving launcher: ``python -m repro.launch.serve [...]``.

Brings up the online-plasticity :class:`repro.serve.Server`, submits a
synthetic per-session spike-raster load (each session is one user's
private network, learning continually via the selected rule × backend),
and reports step latency, throughput, and the session-memory numbers
that make the packed-word "plasticity cache" the headline: bytes per
session and sessions per GiB.

``--ckpt-dir`` saves the full session store on exit and restores from
the latest checkpoint on startup, so a long-running deployment's learned
per-user state survives restarts.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.launch.cli import (add_serve_flags, add_update_flags,
                              engine_config_from_args, serve_config_from_args)
from repro.serve import Request, Server


def synthetic_load(key, *, sessions: int, requests: int, t_steps: int,
                   n_pre: int, rate: float = 0.3) -> list[Request]:
    """A deterministic request stream over ``sessions`` round-robin users."""
    reqs = []
    for i in range(requests):
        sub = jax.random.fold_in(key, i)
        raster = (jax.random.uniform(sub, (t_steps, n_pre)) < rate)
        reqs.append(Request(sid=f"user{i % sessions}",
                            raster=raster.astype(np.float32)))
    return reqs


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    add_serve_flags(ap)
    add_update_flags(ap)
    ap.add_argument("--sessions", type=int, default=8,
                    help="distinct synthetic users in the load")
    ap.add_argument("--requests", type=int, default=32,
                    help="total requests submitted")
    ap.add_argument("--rate", type=float, default=0.3,
                    help="per-step input spike probability of the load")
    ap.add_argument("--ckpt-dir", default=None,
                    help="restore latest checkpoint on start, save on exit")
    args = ap.parse_args()

    cfg = engine_config_from_args(args)
    scfg = serve_config_from_args(args)
    server = Server(cfg, scfg, seed=args.seed)
    if args.ckpt_dir:
        try:
            server.restore(args.ckpt_dir)
            print(f"restored {len(server.store)} sessions "
                  f"from {args.ckpt_dir}")
        except FileNotFoundError:
            print(f"no checkpoint under {args.ckpt_dir}; starting fresh")

    reqs = synthetic_load(jax.random.PRNGKey(args.seed + 1),
                          sessions=args.sessions, requests=args.requests,
                          t_steps=scfg.t_steps, n_pre=cfg.n_pre,
                          rate=args.rate)
    tickets = [server.submit(r) for r in reqs]

    # first step compiles; time the steady state separately
    t0 = time.perf_counter()
    server.step()
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    served = server.shutdown(drain=True)
    dt = time.perf_counter() - t0

    done = sum(server.poll(t) is not None for t in tickets)
    store = server.store
    steps = served * scfg.t_steps
    print(f"served {done}/{args.requests} requests "
          f"({args.sessions} sessions, rule={cfg.rule}, "
          f"backend={cfg.backend})")
    print(f"  first step (compile): {compile_s * 1e3:.1f} ms; drain: "
          f"{served} lanes / {steps} sim-steps in {dt:.3f}s "
          f"({steps / max(dt, 1e-9):.0f} steps/s)")
    print(f"  plasticity cache: {store.state_bytes_per_session()} B/session "
          f"({store.sessions_per_gb():.0f} sessions/GiB); resident "
          f"{store.resident_bytes_per_session()} B/session "
          f"({store.sessions_per_gb(resident=True):.0f} sessions/GiB)")

    if args.ckpt_dir:
        path = server.checkpoint(args.ckpt_dir)
        print(f"  checkpointed {len(store)} sessions -> {path}")


if __name__ == "__main__":
    main()
