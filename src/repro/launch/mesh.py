"""Production mesh definitions.

``make_production_mesh`` is a *function* so importing this module never
touches jax device state (device count is locked at first jax init; the
dry-run sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512``
before any jax import to get placeholder devices).

Geometry (DESIGN.md §6):
  * single-pod: (data=16, model=16)            — 256 chips (one v5e pod)
  * multi-pod : (pod=2, data=16, model=16)     — 512 chips across 2 pods;
    the ``pod`` axis carries pure data parallelism over the slower
    inter-pod links (po2-compressed gradient exchange).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1, pod: int | None = None
                    ) -> Mesh:
    """Small meshes for CPU tests (device count permitting)."""
    if pod is not None:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"))
    return jax.make_mesh((data, model), ("data", "model"))


def describe(mesh: Mesh) -> str:
    return " × ".join(f"{n}={s}" for n, s in zip(mesh.axis_names,
                                                 mesh.devices.shape))
