"""Training launcher: ``python -m repro.launch.train --arch <id> [...]``.

Runs end-to-end on whatever devices exist (CPU smoke scale → TPU pods): a
synthetic-token LM run with the full production control loop — sharded
init, jitted train step, async checkpointing, restart-on-failure,
straggler watchdog.  For the paper's own SNN training path use
``examples/train_snn.py`` (the learning-engine loop has no gradients).

``--engine`` switches to the learning-engine workload: a population of
engine replicas trained on random rasters with the selectable learning
rule (``--rule itp|itp_nocomp|exact|linear|imstdp``) and weight-update
backend (``--backend reference|fused|fused_interpret|sparse``),
reporting synaptic-op throughput — the launcher path for exercising the
fused Pallas datapath (and the counter-rule baselines) end-to-end.  The
``sparse`` backend is the event-driven datapath (``--max-events`` caps
the static event-list length per side).

``--snn <net>`` switches to the paper's network workloads (2-layer SNN,
6-layer DCSNN, 5-layer CSNN) on the same selectable rule and backend,
driving the shared train-to-accuracy loop of
``repro.train.stdp_trainer`` — unsupervised STDP epochs with
homeostasis/WTA competition and the label-assignment evaluation — through
the same CLI builder (``repro.launch.cli``) as ``examples/train_snn.py``:
the conv nets drive the rule's im2col-fused conv kernel, the fc layers
its dense engine kernel — the launcher path for the whole-network fused
datapath.  Every registered rule is kernel-backed (history rules →
``itp_stdp``/``itp_stdp_conv``, counter rules → ``itp_counter``), so the
full rule × backend matrix in ROADMAP.md runs from here; a rule without
a kernel would still be rejected up front with the valid combinations.
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import ARCH_NAMES, get_config, get_smoke_config
from repro.data import LMBatchSpec, lm_batches
from repro.distributed.fault_tolerance import (FailureInjector, RunnerConfig,
                                               TrainingRunner)
from repro.distributed.sharding import use_mesh
from repro.launch import cli
from repro.launch.mesh import describe, make_debug_mesh
from repro.train import (OptimizerConfig, TrainConfig, init_training,
                         make_train_step)


def run_engine_training(args) -> dict:
    """Population engine training on the selected rule + backend.

    Trains ``--replicas`` independent engine replicas for ``--steps`` steps
    on Bernoulli rasters and reports wall-clock + synaptic-op throughput.
    Returns the summary dict (also printed) so tests can call this directly.
    """
    from repro.core.engine import (EngineConfig, init_engine_population,
                                   run_engine_population)

    rule = getattr(args, "rule", "itp")
    cfg = EngineConfig(n_pre=args.engine_pre, n_post=args.engine_post,
                       rule=rule, backend=args.backend,
                       max_events=getattr(args, "max_events", None))
    key = jax.random.PRNGKey(0)
    states = init_engine_population(key, cfg, args.replicas)
    trains = jax.random.bernoulli(
        jax.random.fold_in(key, 1), args.engine_rate,
        (args.replicas, args.steps, cfg.n_pre))

    run = jax.jit(lambda s, x: run_engine_population(s, x, cfg))
    t0 = time.time()
    states, post = jax.block_until_ready(run(states, trains))
    compile_s = time.time() - t0
    t0 = time.time()
    states, post = jax.block_until_ready(run(states, trains))
    run_s = time.time() - t0

    sops = args.replicas * args.steps * cfg.n_pre * cfg.n_post
    summary = {
        "rule": rule,
        "backend": args.backend,
        "replicas": args.replicas,
        "n_pre": cfg.n_pre, "n_post": cfg.n_post, "steps": args.steps,
        "compile_seconds": round(compile_s, 3),
        "run_seconds": round(run_s, 4),
        "sops_per_s": sops / max(run_s, 1e-9),
        "mean_post_rate": float(post.mean()),
    }
    print(f"engine training [{rule} / {args.backend}]: "
          f"{args.replicas} replicas × "
          f"{cfg.n_pre}×{cfg.n_post} × {args.steps} steps — "
          f"{summary['sops_per_s']:.3e} SOP/s "
          f"(compile {compile_s:.2f}s, run {run_s:.3f}s, "
          f"mean post rate {summary['mean_post_rate']:.3f})", flush=True)
    return summary


def run_snn_training(args) -> dict:
    """One of the paper's SNNs, trained to accuracy on rule + backend.

    Drives the shared train-to-accuracy loop
    (``repro.train.stdp_trainer``) — epochs of unsupervised STDP over
    rate-coded stand-in data with the label-assignment evaluation after
    each — through the same ``SNNConfig`` / ``TrainerConfig`` builders as
    ``examples/train_snn.py`` (``repro.launch.cli``).  The conv nets
    (6layer-dcsnn, 5layer-csnn) exercise the im2col-fused conv kernel
    end-to-end.  Reports accuracy plus wall-clock + synaptic-update
    throughput; returns the summary dict (also printed) so tests can call
    this directly, including with legacy ``--steps``-style namespaces.
    """
    from repro.launch import cli
    from repro.models import snn
    from repro.train.stdp_trainer import train_to_accuracy

    net = cli.net_from_args(args)
    cfg = cli.snn_config_from_args(args, net=net)
    tcfg = cli.trainer_config_from_args(args)
    sampler, n_classes = cli.sampler_for(net)
    result = train_to_accuracy(cfg, sampler, n_classes, tcfg, verbose=True)

    # synaptic updates per step: every learnable layer touches its full
    # (fan_in × out) matrix per patch row
    updates = 0
    shapes = [tuple(cfg.input_shape)] + snn._layer_shapes(cfg)
    for spec, in_shape, out_shape in zip(cfg.layers, shapes[:-1], shapes[1:]):
        if spec.kind.startswith("pool"):
            continue
        rows = 1
        for d in out_shape[:-1] or (1,):
            rows *= d
        updates += tcfg.batch * rows * snn._fan_in(spec, in_shape) \
            * spec.out_features
    run_s = result["train_seconds"]
    summary = {
        "net": cfg.name, "rule": cfg.rule, "backend": cfg.backend,
        "batch": tcfg.batch,
        "steps": result["sim_steps"],
        "epochs": tcfg.epochs,
        "run_seconds": round(run_s, 4),
        "sops_per_s": result["sim_steps"] * updates / max(run_s, 1e-9),
        "mean_rate": result["mean_eval_rates"][-1],
        "accuracy_curve": result["accuracy_curve"],
        "final_accuracy": result["final_accuracy"],
        "chance": result["chance"],
    }
    print(f"snn training [{cfg.name} / {cfg.rule} / {cfg.backend}]: "
          f"batch {tcfg.batch} × {result['sim_steps']} steps — "
          f"{summary['sops_per_s']:.3e} SOP/s (train {run_s:.2f}s incl. "
          f"compile), accuracy {summary['final_accuracy']:.3f} "
          f"(chance {summary['chance']:.3f})", flush=True)
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="qwen3-0.6b")
    ap.add_argument("--engine", action="store_true",
                    help="train the ITP-STDP learning engine instead of the "
                         "LM stack")
    # SNN-mode flags come from the shared builder (repro.launch.cli) so
    # this entry point and examples/train_snn.py declare them exactly once;
    # --snn doubles as the mode switch (default None = LM/engine mode) and
    # --batch is shared with the LM path (hence the LM default of 8)
    cli.add_net_flag(ap, "--snn", default=None)
    cli.add_update_flags(ap)
    cli.add_train_flags(ap, batch_default=8)
    ap.add_argument("--engine-pre", type=int, default=256)
    ap.add_argument("--engine-post", type=int, default=256)
    ap.add_argument("--replicas", type=int, default=8)
    ap.add_argument("--engine-rate", type=float, default=0.3,
                    help="Bernoulli input spike rate (--engine and --snn "
                         "modes)")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", choices=("none", "full", "dots"),
                    default="none")
    ap.add_argument("--po2-update", action="store_true",
                    help="ITP-AdamW: po2-quantised optimizer updates")
    ap.add_argument("--data", type=int, default=0,
                    help="data-parallel mesh axis (0 = no mesh)")
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--inject-failure-at", type=int, default=-1)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    if args.net:
        run_snn_training(args)
        return
    if args.engine:
        run_engine_training(args)
        return

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    opt_cfg = OptimizerConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=max(args.steps // 20, 5),
                              po2_update=args.po2_update)
    train_cfg = TrainConfig(remat=args.remat)

    mesh = None
    if args.data > 0:
        mesh = make_debug_mesh(data=args.data, model=args.model)
        print(f"mesh: {describe(mesh)}")

    ctx = use_mesh(mesh) if mesh is not None else use_mesh(None)
    with ctx:
        params, opt_state = init_training(jax.random.PRNGKey(0), cfg, opt_cfg,
                                          mesh)
        step_fn = jax.jit(make_train_step(cfg, opt_cfg, train_cfg, mesh))

        spec = LMBatchSpec(batch=args.batch, seq=args.seq,
                           vocab=cfg.vocab_size)

        def batch_for(step: int):
            return next(lm_batches(jax.random.PRNGKey(1000 + step), spec,
                                   n_steps=1))

        state = {"params": params, "opt": opt_state}

        def wrapped(state, batch):
            p, o, metrics = step_fn(state["params"], state["opt"], batch)
            return {"params": p, "opt": o}, metrics

        runner = TrainingRunner(
            RunnerConfig(ckpt_dir=args.ckpt_dir,
                         ckpt_every=args.ckpt_every),
            wrapped, batch_for)
        injector = None
        if args.inject_failure_at >= 0:
            injector = FailureInjector({args.inject_failure_at})

        t0 = time.time()
        n_logged = [0]

        orig_step = runner.step_fn

        def logging_step(state, batch):
            out, metrics = orig_step(state, batch)
            n = n_logged[0]
            if n % args.log_every == 0:
                loss = float(metrics["loss"])
                toks = float(metrics["tokens"]) * args.log_every
                dt = time.time() - t0
                print(f"step {n:5d}  loss {loss:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"gnorm {float(metrics['grad_norm']):.3f}  "
                      f"({n / max(dt, 1e-9):.2f} it/s)", flush=True)
            n_logged[0] += 1
            return out, metrics

        runner.step_fn = logging_step
        state = runner.run(state, args.steps, injector)
        print(f"done: {args.steps} steps in {time.time() - t0:.1f}s; "
              f"restarts={runner.restarts}; "
              f"stragglers={len(runner.watchdog.stragglers)}")


if __name__ == "__main__":
    main()
