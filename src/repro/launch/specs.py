"""ShapeDtypeStruct stand-ins + shardings for every dry-run cell.

``input_specs(cfg, shape)`` builds the abstract inputs for a given
(architecture × input-shape) pair — weak-type-correct, shardable, zero
device allocation — together with ``step_and_shardings`` which pairs them
with the function the cell lowers:

  * train_*    → ``repro.train.make_train_step``    (params, opt, batch)
  * prefill_*  → last-token-logits forward           (params, batch)
  * decode_* / long_* → ``transformer.decode_step``  (params, cache,
                        tokens, pos)

Modality frontends are stubs per the brief: the VLM cell feeds
precomputed patch embeddings ``vis_embed`` (B, n_vis, vis_dim); musicgen's
EnCodec tokenizer is stubbed by the token stream itself.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.shapes import ShapeSpec
from repro.distributed.sharding import (batch_axes, decode_cache_shardings,
                                        param_shardings)
from repro.models import transformer
from repro.train.optimizer import OptimizerConfig, OptState, init_opt_state
from repro.train.train_step import TrainConfig, make_train_step

SDS = jax.ShapeDtypeStruct


def _sds_like(tree):
    return jax.tree_util.tree_map(
        lambda x: SDS(x.shape, x.dtype), tree)


# ---------------------------------------------------------------------------
# Abstract model/optimizer state
# ---------------------------------------------------------------------------

def abstract_params(cfg):
    return jax.eval_shape(
        lambda k: transformer.init_model(k, cfg), jax.random.PRNGKey(0))


def abstract_opt_state(cfg):
    return jax.eval_shape(init_opt_state, abstract_params(cfg))


def abstract_cache(cfg, shape: ShapeSpec, kv_dtype="bfloat16"):
    dt = jnp.int8 if kv_dtype == "int8" else jnp.bfloat16
    return jax.eval_shape(lambda: transformer.init_decode_cache(
        cfg, shape.global_batch, shape.seq_len, kv_dtype=dt))


# ---------------------------------------------------------------------------
# Batch specs
# ---------------------------------------------------------------------------

def train_batch_specs(cfg, shape: ShapeSpec) -> dict:
    B, S = shape.global_batch, shape.seq_len
    batch = {
        "tokens": SDS((B, S), jnp.int32),
        "labels": SDS((B, S), jnp.int32),
    }
    if cfg.family == "vlm":
        batch["vis_embed"] = SDS((B, cfg.n_vis_tokens, cfg.vis_dim),
                                 jnp.bfloat16)
    return batch


def decode_inputs(cfg, shape: ShapeSpec, kv_dtype="bfloat16"):
    B = shape.global_batch
    inputs = {
        "cache": abstract_cache(cfg, shape, kv_dtype),
        "tokens": SDS((B, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
    }
    if cfg.family == "vlm":
        # cross K/V are precomputed at prefill; pass them via the cache
        hd = cfg.resolved_head_dim
        n_cross = len(cfg.cross_attn_layers)
        cross = SDS((n_cross, B, cfg.n_vis_tokens, cfg.n_kv_heads, hd),
                    jnp.bfloat16)
        inputs["cache"] = inputs["cache"]._replace(cross_k=cross,
                                                   cross_v=cross)
    return inputs


def input_specs(cfg, shape: ShapeSpec, kv_dtype="bfloat16") -> dict:
    """All abstract inputs for one dry-run cell (excluding model state)."""
    if shape.kind in ("train", "prefill"):
        return train_batch_specs(cfg, shape)
    return decode_inputs(cfg, shape, kv_dtype)


# ---------------------------------------------------------------------------
# Step + shardings per cell
# ---------------------------------------------------------------------------

def _batch_shardings(mesh: Mesh, batch: dict) -> dict:
    ax = batch_axes(mesh)
    def one(x):
        return NamedSharding(mesh, P(ax, *([None] * (len(x.shape) - 1))))
    return jax.tree_util.tree_map(one, batch)


@dataclasses.dataclass
class CellPlan:
    """Everything needed to lower one (arch × shape × mesh) cell."""
    fn: Callable                  # the pure step function
    args: tuple                   # abstract args (SDS pytrees)
    in_shardings: tuple
    out_shardings: Any            # None → let GSPMD choose
    donate: tuple = ()


def make_prefill_fn(cfg, train_cfg: TrainConfig = TrainConfig()):
    def step(params, batch):
        kw = {}
        if cfg.family == "vlm":
            kw["vis_embed"] = batch["vis_embed"]
        logits, _ = transformer.forward(
            params, cfg, tokens=batch["tokens"], remat=train_cfg.remat,
            last_logits_only=True, unroll=train_cfg.unroll, **kw)
        return logits
    return step


def plan_cell(cfg, shape: ShapeSpec, mesh: Mesh, *,
              opt_cfg: OptimizerConfig | None = None,
              train_cfg: TrainConfig = TrainConfig(),
              kv_dtype: str = "bfloat16") -> CellPlan:
    """Build the (fn, abstract args, shardings) plan for one cell."""
    from repro.distributed.sharding import use_sharding_profile
    opt_cfg = opt_cfg or OptimizerConfig()
    params = abstract_params(cfg)
    profile = train_cfg.sharding_profile

    def profiled(fn):
        # the profile governs both sharding-tree construction (here) and
        # the activation constraints resolved at trace time (inside jit)
        def wrapped(*a, **kw):
            with use_sharding_profile(profile):
                return fn(*a, **kw)
        return wrapped

    with use_sharding_profile(profile):
        p_sh = param_shardings(cfg, params, mesh)

        if shape.kind == "train":
            batch = train_batch_specs(cfg, shape)
            opt_state = abstract_opt_state(cfg)
            o_sh = OptState(step=NamedSharding(mesh, P()), mu=p_sh, nu=p_sh)
            fn = make_train_step(cfg, opt_cfg, train_cfg, mesh=mesh)
            return CellPlan(
                fn=profiled(fn),
                args=(params, opt_state, batch),
                in_shardings=(p_sh, o_sh, _batch_shardings(mesh, batch)),
                out_shardings=(p_sh, o_sh, None),
                donate=(0, 1),
            )

        if shape.kind == "prefill":
            batch = train_batch_specs(cfg, shape)
            # drop labels: prefill is inference
            batch = {k: v for k, v in batch.items() if k != "labels"}
            fn = make_prefill_fn(cfg, train_cfg)
            return CellPlan(
                fn=profiled(fn),
                args=(params, batch),
                in_shardings=(p_sh, _batch_shardings(mesh, batch)),
                out_shardings=None,
            )

        # decode
        inputs = decode_inputs(cfg, shape, kv_dtype)
        cache = inputs["cache"]
        c_sh = decode_cache_shardings(cache, mesh)

        def fn(params, cache, tokens, pos):
            return transformer.decode_step(params, cfg, cache, pos,
                                           tokens=tokens,
                                           unroll=train_cfg.unroll)

        tok_sh = NamedSharding(
            mesh, P(batch_axes(mesh)
                    if shape.global_batch % _prod(mesh, batch_axes(mesh)) == 0
                    else None, None))
        return CellPlan(
            fn=profiled(fn),
            args=(params, cache, inputs["tokens"], inputs["pos"]),
            in_shardings=(p_sh, c_sh, tok_sh, NamedSharding(mesh, P())),
            out_shardings=(None, c_sh),
            donate=(1,),
        )


def _prod(mesh: Mesh, axes) -> int:
    n = 1
    for a in axes:
        n *= mesh.shape[a]
    return n
