"""Layer-calibrated cost extrapolation for the roofline analysis.

HloCostAnalysis counts a ``lax.scan`` body once, so scanned-module numbers
undercount per-layer work by ~n_layers; fully unrolled modules measure
correctly but take minutes-to-hours to compile at 64 layers × 256 devices
on this host.  For homogeneous layer stacks the per-device cost is exactly
linear in the layer count:

    F(L) = F_out + L · F_body

so two small unrolled compiles (L=2, L=4) at FULL width on the FULL mesh
identify (F_out, F_body) and the full-depth cost follows.  Heterogeneous
stacks solve a small linear system per layer type (hymba: SWA + global
bodies; llama-vision: 5-layer periods).

Validation: against the fully unrolled qwen3-0.6b train_4k measurement the
extrapolated flops/collective bytes agree to <2 % (EXPERIMENTS.md §Roofline).
"""
from __future__ import annotations

import dataclasses
import time

import jax

from repro.launch.specs import plan_cell
from repro.train.train_step import TrainConfig

# metrics we extrapolate linearly in L
_COST_KEYS = ("flops", "bytes accessed", "transcendentals")


def _measure(cfg, shape, mesh, parse_collectives,
             train_cfg=None, kv_dtype: str = "bfloat16") -> dict:
    """Compile the unrolled program for (cfg, shape) and return flat costs."""
    base = train_cfg or TrainConfig()
    plan = plan_cell(cfg, shape, mesh,
                     train_cfg=dataclasses.replace(base, unroll=True),
                     kv_dtype=kv_dtype)
    jitted = jax.jit(plan.fn, in_shardings=plan.in_shardings,
                     out_shardings=plan.out_shardings,
                     donate_argnums=plan.donate)
    compiled = jitted.lower(*plan.args).compile()
    cost = compiled.cost_analysis() or {}
    coll = parse_collectives(compiled.as_text())
    out = {k: float(cost.get(k, 0.0)) for k in _COST_KEYS}
    out["coll_operand"] = coll["total_operand_bytes"]
    out["coll_wire"] = coll["total_wire_bytes"]
    for kind, v in coll.items():
        if isinstance(v, dict):
            out[f"coll_{kind}"] = v["operand_bytes"]
    return out


def _lin(m2: dict, m4: dict, l2: int, l4: int, L: int) -> dict:
    """Solve F = F_out + L·F_body from measurements at l2 < l4 layers."""
    out = {}
    for k in m2:
        body = (m4[k] - m2[k]) / (l4 - l2)
        base = m2[k] - l2 * body
        out[k] = max(base + L * body, 0.0)
    return out


def _reduced(cfg, n_layers: int, **kw):
    return dataclasses.replace(cfg, n_layers=n_layers, **kw)


def extrapolate_cell(cfg, shape, mesh, parse_collectives,
                     verbose: bool = False, train_cfg=None,
                     kv_dtype: str = "bfloat16") -> dict:
    """Extrapolated full-depth per-device costs for one dry-run cell."""
    import functools
    _m = functools.partial(_measure, parse_collectives=parse_collectives,
                           train_cfg=train_cfg, kv_dtype=kv_dtype)
    t0 = time.time()
    fam = cfg.family
    if fam == "hybrid":
        # bodies: sliding-window (swa) and global-attention layers
        swa2 = _m(_reduced(cfg, 2, global_layers=()), shape, mesh)
        swa4 = _m(_reduced(cfg, 4, global_layers=()), shape, mesh)
        mix2 = _m(_reduced(cfg, 2, global_layers=(0,)), shape, mesh)
        n_glb = len(cfg.global_layers)
        n_swa = cfg.n_layers - n_glb
        est = {}
        for k in swa2:
            body_swa = (swa4[k] - swa2[k]) / 2.0
            base = swa2[k] - 2 * body_swa
            body_glb = mix2[k] - base - body_swa
            est[k] = max(base + n_swa * body_swa + n_glb * body_glb, 0.0)
    elif fam == "vlm":
        n_cross = len(cfg.cross_attn_layers)
        period = cfg.n_layers // n_cross
        one = _m(_reduced(cfg, period, cross_attn_layers=(period - 2,)),
                 shape, mesh)
        two = _m(_reduced(cfg, 2 * period,
                          cross_attn_layers=(period - 2, 2 * period - 2)),
                 shape, mesh)
        est = _lin(one, two, 1, 2, n_cross)
    else:
        m2 = _m(_reduced(cfg, 2), shape, mesh)
        m4 = _m(_reduced(cfg, 4), shape, mesh)
        est = _lin(m2, m4, 2, 4, cfg.n_layers)
    est["extrapolation_seconds"] = round(time.time() - t0, 1)
    if verbose:
        print(f"    extrapolated in {est['extrapolation_seconds']}s: "
              f"flops={est['flops']:.3e} coll={est['coll_operand']:.3e}B")
    return est
