"""Shared CLI plumbing for the SNN training entry points.

``examples/train_snn.py`` and ``python -m repro.launch.train --snn`` drive
the same train-to-accuracy loop (``repro.train.stdp_trainer``), so every
flag that feeds ``SNNConfig`` / ``TrainerConfig`` is declared exactly once
here — network / rule / backend / max-events selection, the epoch-level
training knobs, and the homeostasis knobs — and both entry points consume
the same constructors.  The entry point chooses only the *spelling* of the
network selector (``--net`` standalone, ``--snn`` as the launcher's mode
switch); choices, help text, and defaults live here.

The builders accept any ``argparse.Namespace``-shaped object and fall back
to the dataclass defaults for missing attributes, so programmatic callers
(tests, benchmarks) can pass minimal namespaces — including the legacy
launcher shape whose ``--steps`` meant total simulation steps.
"""

from __future__ import annotations

import argparse

from repro import plasticity
from repro.core.engine import EngineConfig
from repro.data import synthetic_digits, synthetic_fashion, synthetic_fault
from repro.kernels.dispatch import BACKENDS
from repro.models import snn
from repro.serve import ServeConfig
from repro.train.stdp_trainer import TrainerConfig

# network → (sampler over the offline stand-in dataset, n_classes); the
# single source both entry points and benchmarks/accuracy.py read
SAMPLERS = {
    "2layer-snn": (lambda k, n: synthetic_digits(k, n), 10),
    "6layer-dcsnn": (lambda k, n: synthetic_fashion(k, n), 10),
    "5layer-csnn": (lambda k, n: synthetic_fault(k, n), 4),
}
assert set(SAMPLERS) == set(snn.PAPER_NETWORKS), (
    "SAMPLERS must cover every network in snn.PAPER_NETWORKS"
)


def sampler_for(net: str) -> tuple:
    """(sampler, n_classes) for one of the paper's networks."""
    return SAMPLERS[net]


def add_net_flag(
    ap: argparse.ArgumentParser,
    flag: str = "--net",
    *,
    default: str | None = "2layer-snn",
) -> None:
    """The network selector — declared here once; entry points pick the
    flag spelling (``--net``, or ``--snn`` doubling as the launcher's mode
    switch with ``default=None``)."""
    ap.add_argument(
        flag,
        dest="net",
        default=default,
        choices=tuple(SAMPLERS),
        help="which of the paper's three networks to train (2-layer fc "
        "SNN, 6-layer conv DCSNN, 5-layer conv CSNN)",
    )


def add_update_flags(ap: argparse.ArgumentParser) -> None:
    """Learning-rule / weight-update-datapath selection (rule × backend)."""
    ap.add_argument(
        "--rule",
        default="itp",
        choices=plasticity.rule_names(),
        help="learning rule (paper Table II axis); every rule runs on "
        "every --backend it supports",
    )
    ap.add_argument(
        "--backend",
        default="reference",
        choices=BACKENDS,
        help="weight-update datapath: pure-jnp reference, the fused "
        "Pallas kernels (interpret mode runs them on CPU), or the "
        "event-driven sparse path; applies to fc and conv layers alike",
    )
    ap.add_argument(
        "--max-events",
        type=int,
        default=None,
        help="sparse backend: static event-list cap per side (default: "
        "uncapped; excess highest-indexed events are dropped)",
    )


def add_train_flags(
    ap: argparse.ArgumentParser,
    *,
    batch_default: int | None = None,
) -> None:
    """Epoch-level training + homeostasis knobs (``None`` defaults defer
    to the ``TrainerConfig`` / ``SNNConfig`` dataclass defaults)."""
    ap.add_argument(
        "--epochs",
        type=int,
        default=None,
        help="training epochs (each followed by a label-assignment "
        "evaluation pass)",
    )
    ap.add_argument(
        "--batches-per-epoch",
        type=int,
        default=None,
        help="rasters per epoch",
    )
    ap.add_argument(
        "--batch",
        type=int,
        default=batch_default,
        help="samples per raster batch",
    )
    ap.add_argument(
        "--t-raster",
        type=int,
        default=None,
        help="simulation steps per raster",
    )
    ap.add_argument(
        "--assign-batches",
        type=int,
        default=None,
        help="held-out batches for the label-assignment pass",
    )
    ap.add_argument(
        "--eval-batches",
        type=int,
        default=None,
        help="held-out batches for the accuracy pass",
    )
    ap.add_argument(
        "--seed",
        type=int,
        default=None,
        help="PRNG seed of the whole run",
    )
    ap.add_argument(
        "--hidden",
        type=int,
        default=None,
        help="hidden width (2layer-snn only)",
    )
    ap.add_argument(
        "--theta-plus",
        type=float,
        default=None,
        help="adaptive-threshold homeostasis increment per spike "
        "(0 disables)",
    )
    ap.add_argument(
        "--theta-tau",
        type=float,
        default=None,
        help="homeostasis threshold decay time constant (steps)",
    )
    ap.add_argument(
        "--inhibition",
        type=float,
        default=None,
        help="soft lateral-inhibition strength",
    )
    ap.add_argument(
        "--hard-wta",
        action="store_true",
        help="hard winner-take-all: only the most-driven super-threshold "
        "neuron fires per sample/position",
    )


def add_serve_flags(ap: argparse.ArgumentParser) -> None:
    """Online-plasticity serving knobs (``python -m repro.launch.serve``).

    The network-shape flags size one session's private engine; the
    serving flags shape the batched step and the store.  ``None``
    defaults defer to the ``ServeConfig`` dataclass defaults.
    """
    ap.add_argument(
        "--n-pre",
        type=int,
        default=64,
        help="presynaptic population size of each session's network",
    )
    ap.add_argument(
        "--n-post",
        type=int,
        default=16,
        help="postsynaptic population size of each session's network",
    )
    ap.add_argument(
        "--depth",
        type=int,
        default=None,
        help="spike-history register depth (<= 8, the packed word width)",
    )
    ap.add_argument(
        "--max-batch",
        type=int,
        default=None,
        help="serving lanes per compiled step (batches are padded to "
        "this, so one program serves all traffic)",
    )
    ap.add_argument(
        "--t-steps",
        type=int,
        default=None,
        help="simulation steps per request raster",
    )
    ap.add_argument(
        "--capacity",
        type=int,
        default=None,
        help="resident-session bound (LRU eviction; default unbounded)",
    )
    ap.add_argument(
        "--seed",
        type=int,
        default=0,
        help="PRNG seed; session weight init is keyed by (seed, sid)",
    )
    ap.add_argument(
        "--theta-plus",
        type=float,
        default=None,
        help="per-session adaptive-threshold increment per post spike "
        "(0 disables homeostasis)",
    )
    ap.add_argument(
        "--theta-tau",
        type=float,
        default=None,
        help="adaptive-threshold decay time constant (steps)",
    )


def engine_config_from_args(args) -> EngineConfig:
    """One serving session's private engine from parsed flags.

    Shares rule/backend selection with :func:`add_update_flags`; only
    flags the user actually set override the ``EngineConfig`` defaults.
    """
    kw = {
        "n_pre": getattr(args, "n_pre", 64),
        "n_post": getattr(args, "n_post", 16),
        "rule": getattr(args, "rule", "itp"),
        "backend": getattr(args, "backend", "reference"),
        "max_events": getattr(args, "max_events", None),
    }
    if getattr(args, "depth", None) is not None:
        kw["depth"] = args.depth
    return EngineConfig(**kw)


def serve_config_from_args(args) -> ServeConfig:
    """Build the ``ServeConfig`` from parsed flags (``None`` defers to
    the dataclass defaults)."""
    kw = {}
    for attr, field in (
        ("max_batch", "max_batch"),
        ("t_steps", "t_steps"),
        ("theta_plus", "theta_plus"),
        ("theta_tau", "theta_tau"),
        ("capacity", "capacity"),
    ):
        v = getattr(args, attr, None)
        if v is not None:
            kw[field] = v
    return ServeConfig(**kw)


def net_from_args(args) -> str:
    """The selected network — ``args.net`` from the shared flag, or the
    legacy ``args.snn`` attribute of programmatic launcher namespaces."""
    net = getattr(args, "net", None) or getattr(args, "snn", None)
    if not net:
        raise ValueError(f"no network selected; choose one of {tuple(SAMPLERS)}")
    return net


def snn_config_from_args(args, *, net: str | None = None) -> snn.SNNConfig:
    """Build the ``SNNConfig`` both entry points share from parsed flags.

    Only flags the user actually set (non-``None``) override the network
    maker's defaults, so e.g. ``mnist_2layer``'s soft inhibition survives
    unless ``--inhibition`` is given.
    """
    net = net or net_from_args(args)
    maker = snn.PAPER_NETWORKS[net]
    kw = {}
    if net == "2layer-snn" and getattr(args, "hidden", None) is not None:
        kw["n_hidden"] = args.hidden
    for name in ("theta_plus", "theta_tau", "inhibition"):
        v = getattr(args, name, None)
        if v is not None:
            kw[name] = v
    if getattr(args, "hard_wta", False):
        kw["hard_wta"] = True
    return maker(
        getattr(args, "rule", "itp"),
        backend=getattr(args, "backend", "reference"),
        max_events=getattr(args, "max_events", None),
        **kw,
    )


def trainer_config_from_args(args) -> TrainerConfig:
    """Build the ``TrainerConfig`` from parsed flags.

    Missing/``None`` attributes fall back to the dataclass defaults.  A
    legacy ``steps`` attribute (the launcher's total-simulation-steps
    knob, still used by ``--engine`` mode and programmatic callers) maps
    to a single epoch of ``steps`` total simulation steps with a short
    evaluation, unless explicit epoch flags override it.
    """
    kw = {}
    for attr, field in (
        ("epochs", "epochs"),
        ("batches_per_epoch", "batches_per_epoch"),
        ("batch", "batch"),
        ("t_raster", "t_steps"),
        ("assign_batches", "assign_batches"),
        ("eval_batches", "eval_batches"),
        ("seed", "seed"),
    ):
        v = getattr(args, attr, None)
        if v is not None:
            kw[field] = v
    steps = getattr(args, "steps", None)
    if steps is not None and "t_steps" not in kw:
        kw["t_steps"] = max(min(steps, 30), 1)
        kw.setdefault("batches_per_epoch", max(steps // kw["t_steps"], 1))
        kw.setdefault("epochs", 1)
        kw.setdefault("assign_batches", 2)
        kw.setdefault("eval_batches", 2)
    return TrainerConfig(**kw)
