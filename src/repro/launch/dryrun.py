import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512")
"""Multi-pod dry-run: lower + compile every (architecture × input-shape ×
mesh) cell against placeholder devices, and extract the roofline terms.

The two lines above MUST run before any jax import (device count locks at
first init) — this module is the only place the 512-device override is set.

Per cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=…, out_shardings=…).lower(*specs)
        compiled = lowered.compile()
        memory_analysis / cost_analysis / collective-bytes(HLO parse)

Outputs one JSON per cell under --out (default experiments/dryrun/) that
benchmarks/roofline.py and EXPERIMENTS.md §Dry-run consume.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-0.6b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all   # every cell, both meshes
"""
import argparse
import json
import re
import time
import traceback

import jax

from repro.configs import ARCH_NAMES, get_config, shapes_for
from repro.configs.shapes import SHAPES
from repro.launch.mesh import describe, make_production_mesh
from repro.launch.specs import plan_cell
from repro.distributed.sharding import use_mesh

# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:  # replica_groups=[G,S]<=[N]: G groups of size S
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip() != ""])
    return 1


def parse_collectives(hlo_text: str) -> dict:
    """Sum collective operand bytes per op kind from optimised HLO text.

    Result shapes are read off each collective line; operand bytes follow
    from the op semantics (all-gather operand = result/g, reduce-scatter
    operand = result·g, others operand = result).  ``wire`` is the ring-
    algorithm per-device byte estimate used for the §Perf discussion.
    """
    stats = {k: {"count": 0, "operand_bytes": 0, "wire_bytes": 0}
             for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip().lstrip("%")
        m = re.match(r"[\w.\-]+ = (.+?) ([\w\-]+)\(", stripped)
        if not m:
            continue
        op = m.group(2)
        # normalise fused variants like all-gather-start
        base = None
        for k in _COLLECTIVES:
            if op == k or op.startswith(k + "-"):
                base = k
                break
        if base is None or op.endswith("-done"):
            continue
        result_bytes = sum(_shape_bytes(d, s)
                           for d, s in _SHAPE_RE.findall(m.group(1)))
        g = max(_group_size(line), 1)
        if base == "all-gather":
            operand = result_bytes // g
            wire = result_bytes * (g - 1) // g
        elif base == "reduce-scatter":
            operand = result_bytes * g
            wire = result_bytes * (g - 1)
        elif base == "all-reduce":
            operand = result_bytes
            wire = 2 * result_bytes * (g - 1) // g
        elif base == "all-to-all":
            operand = result_bytes
            wire = result_bytes * (g - 1) // g
        else:  # collective-permute
            operand = result_bytes
            wire = result_bytes
        stats[base]["count"] += 1
        stats[base]["operand_bytes"] += operand
        stats[base]["wire_bytes"] += wire
    stats["total_operand_bytes"] = sum(
        v["operand_bytes"] for k, v in stats.items() if isinstance(v, dict))
    stats["total_wire_bytes"] = sum(
        v["wire_bytes"] for k, v in stats.items() if isinstance(v, dict))
    return stats


# ---------------------------------------------------------------------------
# Per-cell dry run
# ---------------------------------------------------------------------------

def _analyse_compiled(compiled) -> dict:
    out = {}
    mem = compiled.memory_analysis()
    if mem is not None:
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(mem, attr, None)
            if v is not None:
                out.setdefault("memory", {})[attr] = int(v)
    cost = compiled.cost_analysis()
    if cost:
        keep = ("flops", "bytes accessed", "transcendentals",
                "optimal_seconds")
        out["cost"] = {k: float(v) for k, v in cost.items()
                       if k in keep and isinstance(v, (int, float))}
    out["collectives"] = parse_collectives(compiled.as_text())
    return out


def _lower_and_compile(cfg, shape, mesh, *, unroll: bool):
    from repro.train.train_step import TrainConfig
    plan = plan_cell(cfg, shape, mesh,
                     train_cfg=TrainConfig(unroll=unroll))
    jitted = jax.jit(plan.fn,
                     in_shardings=plan.in_shardings,
                     out_shardings=plan.out_shardings,
                     donate_argnums=plan.donate)
    t0 = time.time()
    lowered = jitted.lower(*plan.args)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0
    return compiled, round(t_lower, 2), round(t_compile, 2)


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             verbose: bool = True, analysis: bool | str = True) -> dict:
    """Lower+compile one cell.

    Two programs per cell: the *scanned* production program (validates the
    real deployment path, gives memory_analysis) and, when ``analysis``,
    a measurement program with true FLOP/collective counts —
    HloCostAnalysis counts while-loop bodies once, so scanned-module
    numbers undercount by ~n_layers.  ``analysis=True`` fully unrolls
    (slow but exact); ``analysis='extrapolate'`` calibrates F_out + L·F_body
    from 2-/4-layer unrolled compiles (fast, <2 % error — see
    launch/extrapolate.py).
    """
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": describe(mesh), "multi_pod": multi_pod,
        "n_devices": int(mesh.devices.size), "ok": False,
    }
    try:
        with use_mesh(mesh):
            compiled, t_l, t_c = _lower_and_compile(cfg, shape, mesh,
                                                    unroll=False)
            rec["time_lower_s"], rec["time_compile_s"] = t_l, t_c
            rec.update(_analyse_compiled(compiled))
            del compiled
            if analysis == "extrapolate":
                from repro.launch.extrapolate import extrapolate_cell
                est = extrapolate_cell(cfg, shape, mesh, parse_collectives)
                rec["cost_extrapolated"] = {
                    "flops": est["flops"],
                    "bytes accessed": est["bytes accessed"],
                    "transcendentals": est.get("transcendentals", 0.0),
                }
                rec["collectives_extrapolated"] = {
                    "total_operand_bytes": est["coll_operand"],
                    "total_wire_bytes": est["coll_wire"],
                    **{k: {"operand_bytes": v} for k, v in est.items()
                       if k.startswith("coll_")
                       and k not in ("coll_operand", "coll_wire")},
                }
                rec["time_extrapolate_s"] = est["extrapolation_seconds"]
            elif analysis:
                compiled_u, t_lu, t_cu = _lower_and_compile(
                    cfg, shape, mesh, unroll=True)
                rec["time_unrolled_s"] = round(t_lu + t_cu, 2)
                a = _analyse_compiled(compiled_u)
                rec["cost_unrolled"] = a.get("cost", {})
                rec["collectives_unrolled"] = a["collectives"]
                rec["memory_unrolled"] = a.get("memory", {})
                del compiled_u
            rec["ok"] = True
            if verbose:
                mem_str = rec.get("memory", {})
                cu = rec.get("cost_unrolled") or \
                    rec.get("cost_extrapolated") or rec.get("cost", {})
                coll = rec.get("collectives_unrolled") or \
                    rec.get("collectives_extrapolated") or \
                    rec.get("collectives", {})
                print(f"[ok] {arch} × {shape_name} × "
                      f"{'multi' if multi_pod else 'single'}-pod  "
                      f"scan {t_l}+{t_c}s unrolled "
                      f"{rec.get('time_unrolled_s', 0)}s  "
                      f"flops={cu.get('flops', 0):.3e}  "
                      f"coll={coll.get('total_operand_bytes', 0):.3e}B "
                      f"temp={mem_str.get('temp_size_in_bytes', 0):.3e}B",
                      flush=True)
    except Exception as e:  # noqa: BLE001 — report, continue the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[FAIL] {arch} × {shape_name} × "
                  f"{'multi' if multi_pod else 'single'}-pod: {rec['error']}",
                  flush=True)
    return rec


def cell_filename(arch: str, shape: str, multi_pod: bool) -> str:
    pod = "multipod" if multi_pod else "singlepod"
    return f"{arch.replace('.', '_')}__{shape}__{pod}.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES)
    ap.add_argument("--shape", choices=tuple(SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--analysis",
                    choices=("auto", "on", "off", "extrapolate"),
                    default="auto",
                    help="measurement pass: on = full unroll (exact, slow); "
                         "extrapolate = 2-/4-layer calibration (fast); "
                         "auto = extrapolate on single-pod cells only "
                         "(the roofline table is single-pod per the brief)")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    if args.all:
        cells = [(a, s.name) for a in ARCH_NAMES
                 for s in shapes_for(get_config(a))]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        cells = [(args.arch, args.shape)]

    pods = {"single": [False], "multi": [True],
            "both": [False, True]}[args.mesh]
    n_fail = 0
    multi_cell = len(cells) * len(pods) > 1
    for arch, shape in cells:
        for multi_pod in pods:
            analysis = {"auto": "extrapolate" if not multi_pod else False,
                        "on": True, "off": False,
                        "extrapolate": "extrapolate"}[args.analysis]
            path = os.path.join(args.out, cell_filename(arch, shape, multi_pod))
            if args.skip_existing and os.path.exists(path):
                with open(path) as f:
                    prev = json.load(f)
                has_analysis = bool(prev.get("cost_unrolled")
                                    or prev.get("cost_extrapolated"))
                if prev.get("ok") and (not analysis or has_analysis):
                    continue
            if multi_cell:
                # one subprocess per cell: a fatal XLA crash (the SPMD
                # partitioner aborts with a Check failure on some
                # sharding bugs) must not kill the sweep
                import subprocess
                import sys
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--mesh", "multi" if multi_pod else "single",
                       "--out", args.out,
                       "--analysis", "on" if analysis else "off"]
                env = dict(os.environ)
                env.pop("XLA_FLAGS", None)   # child sets its own
                r = subprocess.run(cmd, env=env, capture_output=True,
                                   text=True)
                tail = (r.stdout + r.stderr).strip().splitlines()
                print("\n".join(t for t in tail[-2:] if t), flush=True)
                if r.returncode != 0 and not os.path.exists(path):
                    rec = {"arch": arch, "shape": shape,
                           "multi_pod": multi_pod, "ok": False,
                           "error": f"fatal crash rc={r.returncode}",
                           "stderr_tail": "\n".join(tail[-8:])}
                    with open(path, "w") as f:
                        json.dump(rec, f, indent=1)
                with open(path) as f:
                    n_fail += 0 if json.load(f).get("ok") else 1
            else:
                rec = run_cell(arch, shape, multi_pod, analysis=analysis)
                n_fail += 0 if rec["ok"] else 1
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
    print(f"dry-run complete: {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
