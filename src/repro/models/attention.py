"""Attention: GQA/MHA with RoPE, qk-norm, QKV bias, sliding windows,
cross-attention (VLM), and block-wise online-softmax for long sequences.

Long-sequence path: queries are processed in static blocks (Python-unrolled,
so each block's KV extent is a *static* slice — no flops are spent on fully
masked KV blocks, unlike a dense-mask implementation, and XLA's
cost_analysis sees the true flop count).  Within a query block, KV blocks
are consumed by a ``lax.scan`` with the streaming-softmax recurrence, so
peak memory is O(block_q · block_kv) per head instead of O(S²).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import (Params, apply_rope, dense_init,
                                 pdtype, rms_head_norm)

NEG_INF = -1e30


def init_attention(key, cfg, *, cross: bool = False) -> Params:
    hd = cfg.resolved_head_dim
    dt = pdtype(cfg)
    kv_in = cfg.vis_dim if cross else cfg.d_model
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], cfg.d_model, cfg.n_heads * hd, dt),
        "wk": dense_init(ks[1], kv_in, cfg.n_kv_heads * hd, dt),
        "wv": dense_init(ks[2], kv_in, cfg.n_kv_heads * hd, dt),
        "wo": dense_init(ks[3], cfg.n_heads * hd, cfg.d_model, dt),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((cfg.n_heads * hd,), dt)
        p["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
        p["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dt)
    if cfg.qk_norm or cross:
        # llama-3.2 vision cross-attn normalises q/k as well
        p["q_norm"] = jnp.ones((hd,), dt)
        p["k_norm"] = jnp.ones((hd,), dt)
    if cross:
        p["gate_attn"] = jnp.zeros((), dt)   # tanh-gated residual
    return p


def project_qkv(p: Params, x: jax.Array, kv_src: jax.Array, cfg,
                *, positions: jax.Array | None,
                kv_positions: jax.Array | None = None,
                rope: bool = True) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Returns q (B,S,H,hd), k,v (B,T,K,hd); applies qk-norm + RoPE."""
    hd = cfg.resolved_head_dim
    B, S, _ = x.shape
    T = kv_src.shape[1]
    q = x @ p["wq"].astype(x.dtype)
    k = kv_src @ p["wk"].astype(x.dtype)
    v = kv_src @ p["wv"].astype(x.dtype)
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = constrain(q.reshape(B, S, cfg.n_heads, hd), ("batch", None, "tp", None))
    k = constrain(k.reshape(B, T, cfg.n_kv_heads, hd), ("batch", None, "tp", None))
    v = constrain(v.reshape(B, T, cfg.n_kv_heads, hd), ("batch", None, "tp", None))
    if "q_norm" in p:
        q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
        k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    if rope and positions is not None:
        q = apply_rope(q, positions, cfg.rope_theta)
        kp = kv_positions if kv_positions is not None else positions
        k = apply_rope(k, kp, cfg.rope_theta)
    return q, k, v


def _gqa_scores(qb: jax.Array, kb: jax.Array, scale: float) -> jax.Array:
    """(B,bq,K,G,hd) × (B,bt,K,hd) → f32 (B,K,G,bq,bt)."""
    return jnp.einsum("bqkgd,btkd->bkgqt", qb, kb,
                      preferred_element_type=jnp.float32) * scale


def _gqa_accum(pb: jax.Array, vb: jax.Array) -> jax.Array:
    """(B,K,G,bq,bt) × (B,bt,K,hd) → f32 (B,K,G,bq,hd)."""
    return jnp.einsum("bkgqt,btkd->bkgqd", pb.astype(vb.dtype), vb,
                      preferred_element_type=jnp.float32)


def blockwise_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        window: int = 0, block_q: int = 1024,
                        block_kv: int = 1024, q_offset: int = 0) -> jax.Array:
    """Causal (optionally sliding-window) attention, O(block²) memory.

    q: (B,S,H,hd); k,v: (B,T,K,hd) with T ≥ S (self-attention uses T=S;
    chunked prefill may pass a longer KV with ``q_offset``).
    """
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    bq = min(block_q, S)
    bkv = min(block_kv, T)
    if S % bq or T % bkv:
        raise ValueError(f"blocks ({bq},{bkv}) must divide (S={S}, T={T})")
    qr = q.reshape(B, S, K, G, hd)

    out_blocks = []
    for qi in range(S // bq):
        q_lo = q_offset + qi * bq                      # absolute start row
        qb = qr[:, qi * bq:(qi + 1) * bq]
        # static KV extent for this query block
        hi_blk = min((q_lo + bq + bkv - 1) // bkv, T // bkv)
        lo_blk = 0 if window <= 0 else max(0, (q_lo - window + 1) // bkv)
        n_blk = hi_blk - lo_blk
        ks_ = k[:, lo_blk * bkv:hi_blk * bkv].reshape(B, n_blk, bkv, K, hd)
        vs_ = v[:, lo_blk * bkv:hi_blk * bkv].reshape(B, n_blk, bkv, K, hd)
        blk_ids = jnp.arange(lo_blk, hi_blk)

        m0 = jnp.full((B, K, G, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, K, G, bq), jnp.float32)
        a0 = jnp.zeros((B, K, G, bq, hd), jnp.float32)
        q_pos = q_lo + jnp.arange(bq)

        def step(carry, xs):
            m, l, acc = carry
            kb, vb, bi = xs
            s = _gqa_scores(qb, kb, scale)             # (B,K,G,bq,bkv)
            kv_pos = bi * bkv + jnp.arange(bkv)
            mask = q_pos[:, None] >= kv_pos[None, :]
            if window > 0:
                mask &= (q_pos[:, None] - kv_pos[None, :]) < window
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            corr = jnp.exp(m - m_new)
            pexp = jnp.exp(s - m_new[..., None])
            l_new = l * corr + jnp.sum(pexp, axis=-1)
            acc_new = acc * corr[..., None] + _gqa_accum(pexp, vb)
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            step, (m0, l0, a0),
            (ks_.swapaxes(0, 1), vs_.swapaxes(0, 1), blk_ids))
        ob = acc / jnp.maximum(l, 1e-30)[..., None]    # (B,K,G,bq,hd)
        out_blocks.append(ob.transpose(0, 3, 1, 2, 4).reshape(B, bq, H, hd))
    return jnp.concatenate(out_blocks, axis=1).astype(q.dtype)


def dense_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    mask: jax.Array | None) -> jax.Array:
    """Unblocked attention (cross-attention / decode / short sequences)."""
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    scale = 1.0 / math.sqrt(hd)
    s = _gqa_scores(q.reshape(B, S, K, G, hd), k, scale)   # (B,K,G,S,T)
    if mask is not None:
        s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = _gqa_accum(p, v)                                    # (B,K,G,S,hd)
    return o.transpose(0, 3, 1, 2, 4).reshape(B, S, H, hd).astype(q.dtype)


def self_attention_train(p: Params, x: jax.Array, cfg, *,
                         positions: jax.Array, window: int = 0,
                         block_q: int = 1024, block_kv: int = 1024) -> jax.Array:
    """Causal self-attention for the training/prefill path."""
    q, k, v = project_qkv(p, x, x, cfg, positions=positions,
                          rope=cfg.pos_embedding == "rope")
    B, S = x.shape[:2]
    if S <= block_q:  # short sequence: dense with causal mask
        pos = positions[0] if positions.ndim > 1 else positions
        mask = pos[:, None] >= pos[None, :]
        if window > 0:
            mask &= (pos[:, None] - pos[None, :]) < window
        o = dense_attention(q, k, v, mask)
    else:
        o = blockwise_attention(q, k, v, window=window, block_q=block_q,
                                block_kv=block_kv)
    hd = cfg.resolved_head_dim
    return o.reshape(B, S, cfg.n_heads * hd) @ p["wo"].astype(x.dtype)


def cross_attention(p: Params, x: jax.Array, vis_kv: tuple[jax.Array, jax.Array],
                    cfg) -> jax.Array:
    """Cross-attention to precomputed vision K/V (B,Nv,K,hd); tanh-gated."""
    hd = cfg.resolved_head_dim
    B, S, _ = x.shape
    q = (x @ p["wq"].astype(x.dtype)).reshape(B, S, cfg.n_heads, hd)
    q = rms_head_norm(p["q_norm"], q, cfg.norm_eps)
    k, v = vis_kv
    o = dense_attention(q, k, v, None)
    o = o.reshape(B, S, cfg.n_heads * hd) @ p["wo"].astype(x.dtype)
    return jnp.tanh(p["gate_attn"].astype(jnp.float32)).astype(x.dtype) * o


def vision_kv(p: Params, vis_embed: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """Project vision embeddings to K/V once (shared across decode steps)."""
    hd = cfg.resolved_head_dim
    B, Nv, _ = vis_embed.shape
    k = (vis_embed @ p["wk"].astype(vis_embed.dtype)).reshape(B, Nv, cfg.n_kv_heads, hd)
    v = (vis_embed @ p["wv"].astype(vis_embed.dtype)).reshape(B, Nv, cfg.n_kv_heads, hd)
    k = rms_head_norm(p["k_norm"], k, cfg.norm_eps)
    return k, v


def decode_attention(p: Params, x: jax.Array, k_cache: jax.Array,
                     v_cache: jax.Array, pos: jax.Array, cfg, *,
                     window: int = 0) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Single-token decode: query len 1 against the (possibly ring) cache.

    x: (B,1,D); caches: (B,T,K,hd) *already containing* this step's K/V is
    NOT assumed — we project, write at ``pos`` (mod T for ring), and attend.
    Returns (out (B,1,D), k_cache', v_cache').
    """
    B, _, _ = x.shape
    T = k_cache.shape[1]
    hd = cfg.resolved_head_dim
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = project_qkv(p, x, x, cfg, positions=positions,
                                  rope=cfg.pos_embedding == "rope")
    slot = pos % T if window > 0 else pos              # ring for SWA
    k_cache = jax.lax.dynamic_update_slice(
        k_cache, k_new.astype(k_cache.dtype), (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(
        v_cache, v_new.astype(v_cache.dtype), (0, slot, 0, 0))
    # validity: ring cache → all written slots; linear cache → idx ≤ pos
    idx = jnp.arange(T)
    if window > 0:
        valid = idx < jnp.minimum(pos + 1, T)
    else:
        valid = idx <= pos
    o = dense_attention(q, k_cache.astype(q.dtype), v_cache.astype(q.dtype),
                        valid[None, None, None, None, :])
    return (o.reshape(B, 1, cfg.n_heads * hd) @ p["wo"].astype(x.dtype),
            k_cache, v_cache)
