"""Mixture-of-Experts layer: top-k routing with per-group expert capacity.

TPU-native dispatch (GShard/MaxText lineage, gather/scatter formulation):
tokens are grouped (training: one group per batch row), each expert takes
its top-C tokens per group (C = S·k/E·capacity_factor), selected tokens are
gathered into a dense (G, E, C, D) block, experts run as one batched einsum
(MXU-friendly, no ragged shapes), and results scatter-add back.  Tokens
beyond capacity are dropped (standard capacity-based semantics); the
combine weights of dropped tokens are zero so the residual path carries
them unchanged.

Expert parallelism: the expert axis shards over 'model' when E divides the
axis (phi3.5: 16/16); otherwise experts shard internally over d_ff
(qwen2-moe: 1408/16) — see distributed/sharding.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain, current_mesh
from repro.models.layers import Params, dense_init, pdtype


def _ep_active(cfg) -> bool:
    mesh = current_mesh()
    return mesh is not None and cfg.experts_alloc % mesh.shape["model"] == 0


def moe_capacity(cfg, tokens_per_group: int) -> int:
    c = int(tokens_per_group * cfg.n_experts_per_tok * cfg.capacity_factor
            / cfg.n_experts)
    return min(max(c, 1), tokens_per_group)


def init_moe(key, cfg) -> Params:
    ks = jax.random.split(key, 5)
    dt = pdtype(cfg)
    # expert tables allocate experts_alloc rows: padding experts (never
    # routed — their scores stay 0) buy EP divisibility, e.g. qwen2-moe's
    # 60 experts padded to 64 = 4/device on a model=16 axis (6 % compute
    # overcapacity versus TP-inside-expert resharding every layer)
    d, e, f = cfg.d_model, cfg.experts_alloc, cfg.moe_d_ff
    p = {
        "router": dense_init(ks[0], d, cfg.n_experts, dt, scale=0.02),
        "gate": (jax.random.truncated_normal(ks[1], -2, 2, (e, d, f)) / jnp.sqrt(d)).astype(dt),
        "up": (jax.random.truncated_normal(ks[2], -2, 2, (e, d, f)) / jnp.sqrt(d)).astype(dt),
        "down": (jax.random.truncated_normal(ks[3], -2, 2, (e, f, d)) / jnp.sqrt(f)).astype(dt),
    }
    if cfg.shared_d_ff:
        sk = jax.random.split(ks[4], 4)
        p["shared"] = {
            "gate": dense_init(sk[0], d, cfg.shared_d_ff, dt),
            "up": dense_init(sk[1], d, cfg.shared_d_ff, dt),
            "down": dense_init(sk[2], cfg.shared_d_ff, d, dt),
            "route": dense_init(sk[3], d, 1, dt, scale=0.02),
        }
    return p


def apply_moe(p: Params, x: jax.Array, cfg) -> tuple[jax.Array, dict]:
    """x: (B, S, D) → (out (B,S,D), aux losses dict).

    B is the group axis; decode callers reshape (B,1,D) → (1,B,D) first so
    the batch forms one group.
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.n_experts_per_tok
    E_alloc = cfg.experts_alloc
    C = moe_capacity(cfg, S)
    dt = x.dtype

    logits = (x @ p["router"].astype(dt)).astype(jnp.float32)   # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, K)                      # (B,S,K)
    if cfg.norm_topk:
        top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # (B,S,E_alloc) combine scores: prob where chosen else 0; padding
    # experts (index ≥ E) keep all-zero scores → capacity rows dead
    chosen = jax.nn.one_hot(top_i, E_alloc, dtype=jnp.float32)  # (B,S,K,Ea)
    scores = jnp.einsum("bske,bsk->bse", chosen, top_p)

    # per-expert top-C tokens per group
    gate_ec, tok_ec = jax.lax.top_k(scores.swapaxes(1, 2), C)   # (B,E,C)
    live = gate_ec > 0.0                                        # capacity fill

    # gather selected tokens: (B,E,C,D)
    ep = _ep_active(cfg)
    e_spec = ("batch", "tp", None, None) if ep else ("batch", None, None, None)
    f_spec = ("batch", "tp", None, None) if ep else ("batch", None, None, "tp")
    xg = jnp.take_along_axis(x[:, None, :, :],
                             tok_ec[..., None], axis=2)
    xg = constrain(xg, e_spec)
    h = constrain(jnp.einsum("becd,edf->becf", xg, p["gate"].astype(dt)), f_spec)
    u = constrain(jnp.einsum("becd,edf->becf", xg, p["up"].astype(dt)), f_spec)
    y = jnp.einsum("becf,efd->becd", jax.nn.silu(h) * u,
                   p["down"].astype(dt))
    y = constrain(y, e_spec)
    y = y * (gate_ec * live)[..., None].astype(dt)

    # scatter-add back to token positions
    out = jnp.zeros((B, S, D), dt)
    b_idx = jnp.arange(B)[:, None, None]
    out = out.at[b_idx, tok_ec, :].add(y, mode="drop")

    if cfg.shared_d_ff:
        sp = p["shared"]
        g = jax.nn.silu(x @ sp["gate"].astype(dt)) * (x @ sp["up"].astype(dt))
        shared = (g @ sp["down"].astype(dt))
        route = jax.nn.sigmoid((x @ sp["route"].astype(dt)).astype(jnp.float32))
        out = out + shared * route.astype(dt)

    # aux losses: Switch load-balance + router z-loss (real experts only)
    density = jnp.mean(chosen[..., :E].sum(axis=2), axis=(0, 1))
    router_mean = jnp.mean(probs, axis=(0, 1))
    aux = E * jnp.sum(density * router_mean)
    zloss = jnp.mean(jax.scipy.special.logsumexp(logits, axis=-1) ** 2)
    losses = {"moe_aux": cfg.router_aux_weight * aux,
              "moe_z": cfg.router_z_weight * zloss}
    return out, losses
