"""Shared model layers: norms, rotary/sinusoidal positions, MLPs, embeddings.

Pure-function style: ``init_*`` builds a param pytree, the matching apply
function consumes it.  Compute runs in ``cfg.dtype`` (bf16 by default) with
fp32 master params; norm statistics and softmax always accumulate in fp32.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain

Params = dict[str, Any]


def cdtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.dtype)


def pdtype(cfg) -> jnp.dtype:
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, (d_in, d_out)) * scale
            ).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg, d: int) -> Params:
    p = {"scale": jnp.ones((d,), pdtype(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), pdtype(cfg))
    return p


def apply_norm(p: Params, x: jax.Array, cfg) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


def rms_head_norm(scale: jax.Array, x: jax.Array, eps: float) -> jax.Array:
    """Per-head RMSNorm on (..., head_dim) — qwen3 qk_norm."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotate-half RoPE.  x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., seq, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(positions: jax.Array, d_model: int) -> jax.Array:
    """Classic transformer sinusoidal embedding (musicgen)."""
    half = d_model // 2
    freqs = jnp.exp(-math.log(10_000.0) * jnp.arange(half, dtype=jnp.float32)
                    / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_model: int, d_ff: int) -> Params:
    ks = jax.random.split(key, 3)
    dt = pdtype(cfg)
    if cfg.mlp == "swiglu":
        return {
            "gate": dense_init(ks[0], d_model, d_ff, dt),
            "up": dense_init(ks[1], d_model, d_ff, dt),
            "down": dense_init(ks[2], d_ff, d_model, dt),
        }
    return {
        "up": dense_init(ks[0], d_model, d_ff, dt),
        "up_bias": jnp.zeros((d_ff,), dt),
        "down": dense_init(ks[1], d_ff, d_model, dt),
        "down_bias": jnp.zeros((d_model,), dt),
    }


def apply_mlp(p: Params, x: jax.Array, cfg) -> jax.Array:
    dt = x.dtype
    if cfg.mlp == "swiglu":
        g = constrain(x @ p["gate"].astype(dt), ("batch", None, "tp"))
        u = constrain(x @ p["up"].astype(dt), ("batch", None, "tp"))
        return (jax.nn.silu(g) * u) @ p["down"].astype(dt)
    h = x @ p["up"].astype(dt) + p["up_bias"].astype(dt)
    h = jax.nn.gelu(constrain(h, ("batch", None, "tp")))
    return h @ p["down"].astype(dt) + p["down_bias"].astype(dt)


# ---------------------------------------------------------------------------
# Embeddings / LM head
# ---------------------------------------------------------------------------

def init_embedding(key, cfg) -> Params:
    dt = pdtype(cfg)
    p = {"tok": (jax.random.normal(key, (cfg.vocab_size, cfg.d_model)) * 0.02
                 ).astype(dt)}
    if not cfg.tie_embeddings:
        p["out"] = dense_init(jax.random.fold_in(key, 1), cfg.d_model,
                              cfg.vocab_size, dt)
    return p


def embed(p: Params, tokens: jax.Array, cfg) -> jax.Array:
    return p["tok"].astype(cdtype(cfg))[tokens]


def unembed(p: Params, x: jax.Array, cfg) -> jax.Array:
    if cfg.tie_embeddings:
        logits = x @ p["tok"].astype(x.dtype).T
    else:
        logits = x @ p["out"].astype(x.dtype)
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits
