"""Model configuration shared by every assigned architecture.

One dataclass covers the whole LM family (dense / MoE / SSM / hybrid /
VLM / audio); family-specific fields are zero/empty when unused.  Configs
are pure data — the model code in ``repro.models`` interprets them.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 → d_model // n_heads

    # --- attention features -------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False
    attn_window: int = 0            # sliding-window size; 0 = full attention
    global_layers: Tuple[int, ...] = ()   # layers forced to full attention
    rope_theta: float = 10_000.0
    pos_embedding: str = "rope"     # rope | sinusoidal
    norm: str = "rmsnorm"           # rmsnorm | layernorm
    norm_eps: float = 1e-6
    mlp: str = "swiglu"             # swiglu | gelu
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # --- MoE -----------------------------------------------------------------
    n_experts: int = 0
    n_experts_per_tok: int = 0
    n_experts_padded: int = 0       # pad expert tables for EP divisibility
                                    # (padding experts are never routed)
    moe_d_ff: int = 0               # per-expert FFN width
    shared_d_ff: int = 0            # shared-expert width (0 = none)
    norm_topk: bool = False
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.001
    router_z_weight: float = 0.0001

    # --- SSM (mamba2 / hybrid) ----------------------------------------------
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    d_conv: int = 4
    ssd_chunk: int = 256

    # --- VLM (cross-attention) ----------------------------------------------
    cross_attn_layers: Tuple[int, ...] = ()
    n_vis_tokens: int = 0
    vis_dim: int = 0

    # --- numerics ------------------------------------------------------------
    dtype: str = "bfloat16"         # activation/compute dtype
    param_dtype: str = "float32"    # master parameter dtype

    # -------------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def has_attention(self) -> bool:
        return self.family != "ssm"

    @property
    def has_ssm(self) -> bool:
        return self.family in ("ssm", "hybrid")

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def experts_alloc(self) -> int:
        """Allocated expert count (≥ n_experts; padded for EP)."""
        return max(self.n_experts, self.n_experts_padded)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic decode: SSM or sliding-window/hybrid archs."""
        return self.family == "ssm" or (self.family == "hybrid")

    def param_count(self) -> int:
        """Analytic parameter count (used for roofline MODEL_FLOPS)."""
        d, v, L = self.d_model, self.vocab_size, self.n_layers
        hd = self.resolved_head_dim if self.n_heads else 0
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d
        per_layer = 0
        if self.has_attention:
            q = d * self.n_heads * hd
            kv = 2 * d * self.n_kv_heads * hd
            o = self.n_heads * hd * d
            per_layer += q + kv + o
        if self.family == "vlm":
            # cross-attn layers replace self-attn: q/o from d_model, k/v
            # from vis_dim; their FFN is already in per_layer below
            n_cross = len(self.cross_attn_layers)
            self_attn = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                         + self.n_heads * hd * d)
            cross = (d * self.n_heads * hd + 2 * self.vis_dim * self.n_kv_heads * hd
                     + self.n_heads * hd * d)
            n += n_cross * (cross - self_attn)
        if self.has_ssm:
            di, ns, g = self.d_inner, self.ssm_state, self.ssm_groups
            heads = self.ssm_heads
            in_proj = d * (2 * di + 2 * g * ns + heads)
            conv = (di + 2 * g * ns) * self.d_conv
            out = di * d
            per_layer += in_proj + conv + out + 3 * heads  # A, D, dt_bias
        if self.is_moe:
            per_layer += d * self.n_experts                       # router
            per_layer += self.n_experts * 3 * d * self.moe_d_ff   # experts
            if self.shared_d_ff:
                per_layer += 3 * d * self.shared_d_ff + d         # + gate
        elif self.d_ff:
            mult = 3 if self.mlp == "swiglu" else 2
            per_layer += mult * d * self.d_ff
        n += L * per_layer
        n += L * 2 * d + d  # norms (approx)
        return n

    def active_param_count(self) -> int:
        """Active parameters per token (MoE: only routed top-k experts)."""
        if not self.is_moe:
            return self.param_count()
        full = self.param_count()
        inactive = (self.n_experts - self.n_experts_per_tok) * 3 * self.d_model \
            * self.moe_d_ff * self.n_layers
        return full - inactive
