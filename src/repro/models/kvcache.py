"""KV-cache containers for decode, including int8-quantised storage.

The int8 path stores per-(token, head) symmetric scales — amax over the
head_dim vector — which keeps dequantisation a fused elementwise multiply
on the attention read path.  At 512k-token contexts the KV cache dominates
serving HBM (DESIGN.md §6); int8 halves it vs bf16 with <0.5 % logit RMSE
(tests/test_models.py), and is thematically the paper's own 8-bit trick
applied to the serving substrate.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class KVCache(NamedTuple):
    """Stacked-over-layers cache: k/v (L, B, T, K, hd)."""
    k: jax.Array
    v: jax.Array
    k_scale: jax.Array | None = None   # (L, B, T, K, 1) when int8
    v_scale: jax.Array | None = None

    @property
    def quantised(self) -> bool:
        return self.k.dtype == jnp.int8


def init_kv_cache(n_layers: int, batch: int, max_t: int, n_kv: int,
                  head_dim: int, dtype=jnp.bfloat16) -> KVCache:
    shape = (n_layers, batch, max_t, n_kv, head_dim)
    if dtype == jnp.int8:
        return KVCache(
            k=jnp.zeros(shape, jnp.int8), v=jnp.zeros(shape, jnp.int8),
            k_scale=jnp.ones(shape[:-1] + (1,), jnp.float32),
            v_scale=jnp.ones(shape[:-1] + (1,), jnp.float32))
    return KVCache(k=jnp.zeros(shape, dtype), v=jnp.zeros(shape, dtype))


def quantise_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """bf16 (…, hd) → (int8 values, f32 scale (…, 1))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale


def dequantise_kv(q: jax.Array, scale: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def cache_write(cache_k: jax.Array, cache_v: jax.Array,
                k_scale: jax.Array | None, v_scale: jax.Array | None,
                k_new: jax.Array, v_new: jax.Array, slot: jax.Array):
    """Write one step's K/V at ``slot`` for a single layer's (B,T,K,hd) slice."""
    if cache_k.dtype == jnp.int8:
        kq, ks = quantise_kv(k_new)
        vq, vs = quantise_kv(v_new)
        cache_k = jax.lax.dynamic_update_slice(cache_k, kq, (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, vq, (0, slot, 0, 0))
        k_scale = jax.lax.dynamic_update_slice(k_scale, ks, (0, slot, 0, 0))
        v_scale = jax.lax.dynamic_update_slice(v_scale, vs, (0, slot, 0, 0))
    else:
        cache_k = jax.lax.dynamic_update_slice(
            cache_k, k_new.astype(cache_k.dtype), (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(
            cache_v, v_new.astype(cache_v.dtype), (0, slot, 0, 0))
    return cache_k, cache_v, k_scale, v_scale


def cache_read(cache_k: jax.Array, cache_v: jax.Array,
               k_scale: jax.Array | None, v_scale: jax.Array | None,
               dtype=jnp.bfloat16) -> tuple[jax.Array, jax.Array]:
    if cache_k.dtype == jnp.int8:
        return (dequantise_kv(cache_k, k_scale, dtype),
                dequantise_kv(cache_v, v_scale, dtype))
    return cache_k.astype(dtype), cache_v.astype(dtype)
