"""Mamba2 mixer with SSD (state-space duality) — arXiv:2405.21060.

Training/prefill uses the chunked dual form: within a chunk the model is a
masked-attention-like quadratic einsum (MXU work), across chunks a linear
recurrence over the per-chunk summarised states (a `lax.scan` carrying the
(heads, d_state, head_dim) state).  One scan pass produces both the
intra-chunk (diagonal-block) and inter-chunk (low-rank) contributions, so
nothing is recomputed.

Group handling keeps the (groups, heads-per-group) factorisation inside the
einsums — B/C are never materialised per-head (mamba2-1.3b has 1 group
feeding 64 heads; broadcasting would cost 64× the B/C bytes).

Decode is the O(1) recurrent form: S' = exp(AΔ)·S + Δ·B⊗x, y = C·S' + D·x.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models.layers import Params, dense_init, pdtype


def ssm_dims(cfg):
    di = cfg.ssm_expand * cfg.d_model
    heads = di // cfg.ssm_head_dim
    g = cfg.ssm_groups
    conv_dim = di + 2 * g * cfg.ssm_state
    return di, heads, g, conv_dim


def init_ssm(key, cfg) -> Params:
    di, heads, g, conv_dim = ssm_dims(cfg)
    n = cfg.ssm_state
    dt_p = pdtype(cfg)
    ks = jax.random.split(key, 6)
    # z / xBC / dt projections are separate matrices so each output dim is
    # independently TP-shardable (slicing a sharded fused dim would force
    # GSPMD reshards at every layer)
    p = {
        "wz": dense_init(ks[3], cfg.d_model, di, dt_p),
        "wxbc": dense_init(ks[0], cfg.d_model, conv_dim, dt_p),
        "wdt": dense_init(ks[4], cfg.d_model, heads, dt_p),
        "conv_w": (jax.random.normal(ks[1], (cfg.d_conv, conv_dim)) * 0.1
                   ).astype(dt_p),
        "conv_b": jnp.zeros((conv_dim,), dt_p),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, heads)).astype(dt_p),
        "d_skip": jnp.ones((heads,), dt_p),
        "dt_bias": jnp.zeros((heads,), dt_p),
        "norm_scale": jnp.ones((di,), dt_p),
        "out_proj": dense_init(ks[2], di, cfg.d_model, dt_p),
    }
    return p


class SSMCache(NamedTuple):
    conv: jax.Array    # (B, d_conv-1, conv_dim)
    state: jax.Array   # (B, g, r, N, P) — r = heads per group


def init_ssm_cache(cfg, batch: int, dtype=jnp.float32) -> SSMCache:
    di, heads, g, conv_dim = ssm_dims(cfg)
    r = heads // g
    return SSMCache(
        conv=jnp.zeros((batch, cfg.d_conv - 1, conv_dim), dtype),
        state=jnp.zeros((batch, g, r, cfg.ssm_state, cfg.ssm_head_dim), dtype),
    )


def _gated_rmsnorm(y: jax.Array, z: jax.Array, scale: jax.Array,
                   eps: float) -> jax.Array:
    yf = (y * jax.nn.silu(z)).astype(jnp.float32)
    ms = jnp.mean(jnp.square(yf), axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)).astype(y.dtype)


def _split_proj(p: Params, u: jax.Array, cfg):
    z = constrain(u @ p["wz"].astype(u.dtype), ("batch", None, "tp"))
    xbc = constrain(u @ p["wxbc"].astype(u.dtype), ("batch", None, "tp"))
    dt = u @ p["wdt"].astype(u.dtype)
    return z, xbc, dt


def ssd_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b_in: jax.Array,
             c_in: jax.Array, chunk: int, s0: jax.Array | None = None
             ) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD.

    x:  (B, L, g, r, P) inputs per head
    dt: (B, L, g, r)    positive step sizes
    a:  (g, r)          negative decay rates
    b_in/c_in: (B, L, g, N)
    Returns (y (B,L,g,r,P), final state (B,g,r,N,P)).
    """
    B, L, g, r, P = x.shape
    N = b_in.shape[-1]
    nc = L // chunk
    if L % chunk:
        raise ValueError(f"chunk {chunk} must divide L={L}")

    xc = x.reshape(B, nc, chunk, g, r, P).swapaxes(0, 1)
    dtc = dt.reshape(B, nc, chunk, g, r).swapaxes(0, 1)
    bc = b_in.reshape(B, nc, chunk, g, N).swapaxes(0, 1)
    cc = c_in.reshape(B, nc, chunk, g, N).swapaxes(0, 1)

    if s0 is None:
        s0 = jnp.zeros((B, g, r, N, P), jnp.float32)
    ii = jnp.arange(chunk)
    causal = (ii[:, None] >= ii[None, :]).astype(jnp.float32)

    def step(S, inp):
        x_k, dt_k, b_k, c_k = inp                     # chunk-local tensors
        a_bar = dt_k.astype(jnp.float32) * a          # (B,Lc,g,r) ≤ 0
        a_cum = jnp.cumsum(a_bar, axis=1)
        a_sum = a_cum[:, -1]                          # (B,g,r)
        xb = (x_k * dt_k[..., None]).astype(jnp.float32)

        # intra-chunk quadratic (diagonal block); mask in log space so the
        # anti-causal half never evaluates exp(+large) (inf·0 = NaN)
        seg = a_cum[:, :, None] - a_cum[:, None]       # (B,i,j,g,r)
        seg = jnp.where(causal[None, :, :, None, None] > 0, seg, -jnp.inf)
        l_mat = jnp.exp(seg)
        cb = jnp.einsum("bign,bjgn->bijg", c_k.astype(jnp.float32),
                        b_k.astype(jnp.float32))
        y = jnp.einsum("bijg,bijgr,bjgrp->bigrp", cb, l_mat, xb)

        # inter-chunk contribution from the carried state
        y = y + jnp.einsum("bign,bgrnp,bigr->bigrp",
                           c_k.astype(jnp.float32), S, jnp.exp(a_cum))

        # state update for the next chunk
        decay = jnp.exp(a_sum[:, None] - a_cum)       # (B,j,g,r)
        s_new = S * jnp.exp(a_sum)[..., None, None] \
            + jnp.einsum("bjgn,bjgr,bjgrp->bgrnp", b_k.astype(jnp.float32),
                         decay, xb)
        return s_new, y.astype(x.dtype)

    s_fin, ys = jax.lax.scan(step, s0, (xc, dtc, bc, cc))
    y = ys.swapaxes(0, 1).reshape(B, L, g, r, P)
    return y, s_fin


def apply_ssm_train(p: Params, u: jax.Array, cfg) -> jax.Array:
    """Full-sequence mixer (training/prefill).  u: (B, L, d_model)."""
    di, heads, g, conv_dim = ssm_dims(cfg)
    n, P = cfg.ssm_state, cfg.ssm_head_dim
    r = heads // g
    B, L, _ = u.shape
    z, xbc, dt_raw = _split_proj(p, u, cfg)

    # causal depthwise conv (width d_conv) + silu
    w = p["conv_w"].astype(xbc.dtype)                 # (d_conv, conv_dim)
    xp = jnp.pad(xbc, ((0, 0), (cfg.d_conv - 1, 0), (0, 0)))
    conv = sum(xp[:, i:i + L] * w[i] for i in range(cfg.d_conv))
    xbc = jax.nn.silu(conv + p["conv_b"].astype(xbc.dtype))

    x = xbc[..., :di].reshape(B, L, g, r, P)
    b_in = xbc[..., di:di + g * n].reshape(B, L, g, n)
    c_in = xbc[..., di + g * n:].reshape(B, L, g, n)
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    dt = dt.reshape(B, L, g, r)
    a = -jnp.exp(p["a_log"].astype(jnp.float32)).reshape(g, r)

    y, _ = ssd_scan(x, dt, a, b_in, c_in, cfg.ssd_chunk)
    y = y + p["d_skip"].astype(y.dtype).reshape(g, r)[None, None, :, :, None] * x
    y = y.reshape(B, L, di)
    y = _gated_rmsnorm(y, z, p["norm_scale"], cfg.norm_eps)
    return y @ p["out_proj"].astype(u.dtype)


def apply_ssm_decode(p: Params, u: jax.Array, cache: SSMCache, cfg
                     ) -> tuple[jax.Array, SSMCache]:
    """Single-token recurrent step.  u: (B, 1, d_model)."""
    di, heads, g, conv_dim = ssm_dims(cfg)
    n, P = cfg.ssm_state, cfg.ssm_head_dim
    r = heads // g
    B = u.shape[0]
    z, xbc_new, dt_raw = _split_proj(p, u, cfg)       # (B,1,·)

    # conv ring: window = [conv_state, x_new]; cache stays f32, compute in
    # the activation dtype so the decode carry dtype is stable under scan
    win = jnp.concatenate([cache.conv.astype(xbc_new.dtype), xbc_new], axis=1)
    w = p["conv_w"].astype(win.dtype)                 # (B,d_conv,·)
    conv = jnp.einsum("bkc,kc->bc", win, w) + p["conv_b"].astype(win.dtype)
    xbc = jax.nn.silu(conv)[:, None, :]               # (B,1,conv_dim)
    conv_cache = win[:, 1:].astype(cache.conv.dtype)

    x = xbc[..., :di].reshape(B, g, r, P)
    b_in = xbc[..., di:di + g * n].reshape(B, g, n)
    c_in = xbc[..., di + g * n:].reshape(B, g, n)
    dt = jax.nn.softplus(dt_raw[:, 0].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32)).reshape(B, g, r)
    a = -jnp.exp(p["a_log"].astype(jnp.float32)).reshape(g, r)

    decay = jnp.exp(dt * a)                           # (B,g,r)
    xb = (x * dt[..., None]).astype(jnp.float32)
    state = cache.state * decay[..., None, None] \
        + jnp.einsum("bgn,bgrp->bgrnp", b_in.astype(jnp.float32), xb)
    y = jnp.einsum("bgn,bgrnp->bgrp", c_in.astype(jnp.float32), state)
    y = y.astype(u.dtype) + p["d_skip"].astype(u.dtype).reshape(g, r)[None, :, :, None] * x
    y = y.reshape(B, 1, di)
    y = _gated_rmsnorm(y, z, p["norm_scale"], cfg.norm_eps)
    return y @ p["out_proj"].astype(u.dtype), SSMCache(conv=conv_cache,
                                                       state=state)
