"""Decoder backbone covering all assigned architecture families.

Layer stacks are **scanned with stacked parameters** (MaxText-style): the
HLO contains each distinct layer body once, which keeps 64-layer × 512-device
SPMD compiles tractable and is what production frameworks ship.

Family-specific structure:
  dense / moe / audio : homogeneous scan over n_layers
  ssm (mamba2)        : homogeneous scan, no attention, no MLP (d_ff=0)
  hybrid (hymba)      : global-attention layers are Python-unrolled around
                        scans of the sliding-window groups (windows must be
                        static for the block-sparse attention path)
  vlm (llama-vision)  : scan over periods of (4 self layers + 1 cross layer)

Decode threads per-layer KV/SSM caches through the same scans as xs/ys.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import attention as attn
from repro.models import kvcache as kvc
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_mlp, apply_norm, cdtype, embed,
                                 init_embedding, init_mlp, init_norm,
                                 sinusoidal_positions, unembed)

Params = dict[str, Any]

REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def _scan_layers(body, carry, stacked, unroll: bool = False):
    """``lax.scan`` over stacked layer params, or a Python unroll.

    The unrolled form exists for *measurement*: XLA's HloCostAnalysis
    counts a while-loop body once (not × trip count), so the dry-run
    lowers unrolled modules to get true FLOP/byte/collective counts; the
    production path stays scanned (compact HLO).  Outputs are stacked to
    match scan's ys contract.
    """
    if not unroll:
        return jax.lax.scan(body, carry, stacked)
    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    ys = []
    for i in range(n):
        layer = jax.tree_util.tree_map(lambda a: a[i], stacked)
        carry, y = body(carry, layer)
        ys.append(y)
    if ys and ys[0] is not None:
        ys = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *ys)
    else:
        ys = None
    return carry, ys


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_block(key, cfg, *, cross: bool = False) -> Params:
    ks = jax.random.split(key, 6)
    p: Params = {"norm1": init_norm(cfg, cfg.d_model)}
    if cfg.family == "ssm":
        p["ssm"] = ssm_mod.init_ssm(ks[0], cfg)
        return p
    if cross:
        p["attn"] = attn.init_attention(ks[0], cfg, cross=True)
    else:
        p["attn"] = attn.init_attention(ks[0], cfg)
    if cfg.family == "hybrid":
        p["ssm"] = ssm_mod.init_ssm(ks[1], cfg)
        p["norm_attn"] = init_norm(cfg, cfg.d_model)
        p["norm_ssm"] = init_norm(cfg, cfg.d_model)
    p["norm2"] = init_norm(cfg, cfg.d_model)
    if cfg.is_moe and not cross:
        p["moe"] = moe_mod.init_moe(ks[2], cfg)
    else:
        p["mlp"] = init_mlp(ks[3], cfg, cfg.d_model, cfg.d_ff)
    return p


def _stack_init(key, cfg, n: int, **kw) -> Params:
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _init_block(k, cfg, **kw))(keys)


def hymba_layer_groups(cfg) -> tuple[list[int], list[list[int]]]:
    """Global layer ids + the sliding-window runs between them."""
    glb = sorted(cfg.global_layers)
    runs, prev = [], 0
    for g in glb + [cfg.n_layers]:
        runs.append([i for i in range(prev, g)])
        prev = g + 1
    return glb, runs


def init_model(key, cfg) -> Params:
    k_embed, k_blocks, k_final = jax.random.split(key, 3)
    params: Params = {"embed": init_embedding(k_embed, cfg),
                      "final_norm": init_norm(cfg, cfg.d_model)}
    if cfg.family == "vlm":
        n_cross = len(cfg.cross_attn_layers)
        period = cfg.n_layers // n_cross
        kp = jax.random.split(k_blocks, n_cross)

        def init_period(k):
            k1, k2 = jax.random.split(k)
            return {"self": _stack_init(k1, cfg, period - 1),
                    "cross": _init_block(k2, cfg, cross=True)}

        params["periods"] = jax.vmap(init_period)(kp)
    elif cfg.family == "hybrid":
        glb, runs = hymba_layer_groups(cfg)
        params["global_blocks"] = _stack_init(k_blocks, cfg, len(glb))
        n_swa = cfg.n_layers - len(glb)
        params["swa_blocks"] = _stack_init(jax.random.fold_in(k_blocks, 1),
                                           cfg, n_swa)
    else:
        params["blocks"] = _stack_init(k_blocks, cfg, cfg.n_layers)
    return params


# ---------------------------------------------------------------------------
# Train / prefill forward
# ---------------------------------------------------------------------------

def _dense_block_train(bp: Params, x: jax.Array, cfg, positions, window: int):
    x = constrain(x, ("batch", None, None))
    h = apply_norm(bp["norm1"], x, cfg)
    x = x + attn.self_attention_train(bp["attn"], h, cfg,
                                      positions=positions, window=window)
    h = apply_norm(bp["norm2"], x, cfg)
    if "moe" in bp:
        m, losses = moe_mod.apply_moe(bp["moe"], h, cfg)
    else:
        m, losses = apply_mlp(bp["mlp"], h, cfg), {}
    return x + m, losses


def _ssm_block_train(bp: Params, x: jax.Array, cfg):
    x = constrain(x, ("batch", None, None))
    h = apply_norm(bp["norm1"], x, cfg)
    return x + ssm_mod.apply_ssm_train(bp["ssm"], h, cfg)


def _hybrid_block_train(bp: Params, x: jax.Array, cfg, positions, window: int):
    x = constrain(x, ("batch", None, None))
    h = apply_norm(bp["norm1"], x, cfg)
    a = attn.self_attention_train(bp["attn"], h, cfg, positions=positions,
                                  window=window)
    s = ssm_mod.apply_ssm_train(bp["ssm"], h, cfg)
    x = x + 0.5 * (apply_norm(bp["norm_attn"], a, cfg)
                   + apply_norm(bp["norm_ssm"], s, cfg))
    h = apply_norm(bp["norm2"], x, cfg)
    return x + apply_mlp(bp["mlp"], h, cfg)


def _cross_block_train(bp: Params, x: jax.Array, cfg, vis_embed):
    h = apply_norm(bp["norm1"], x, cfg)
    kv = attn.vision_kv(bp["attn"], vis_embed, cfg)
    x = x + attn.cross_attention(bp["attn"], h, kv, cfg)
    h = apply_norm(bp["norm2"], x, cfg)
    return x + apply_mlp(bp["mlp"], h, cfg)


def _maybe_remat(fn, policy: str | None):
    if policy is None or policy == "none":
        return fn
    return jax.checkpoint(fn, policy=REMAT_POLICIES[policy],
                          prevent_cse=False)


def forward(params: Params, cfg, *, tokens: jax.Array | None = None,
            embeds: jax.Array | None = None,
            vis_embed: jax.Array | None = None,
            remat: str = "full",
            last_logits_only: bool = False,
            unroll: bool = False) -> tuple[jax.Array, dict]:
    """Training/prefill forward pass → (logits (B,S,V), aux-loss dict).

    ``last_logits_only`` unembeds just the final position (B,1,V) — the
    serving-prefill path, which never materialises the (B,S,V) tensor.
    """
    if embeds is not None:
        x = embeds.astype(cdtype(cfg))
        B, S = x.shape[:2]
    else:
        B, S = tokens.shape
        x = embed(params["embed"], tokens, cfg)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    if cfg.pos_embedding == "sinusoidal":
        x = x + sinusoidal_positions(positions, cfg.d_model).astype(x.dtype)

    aux = {"moe_aux": jnp.zeros((), jnp.float32),
           "moe_z": jnp.zeros((), jnp.float32)}

    if cfg.family == "ssm":
        body = _maybe_remat(lambda c, bp: (_ssm_block_train(bp, c, cfg), None),
                            remat)
        x, _ = _scan_layers(body, x, params["blocks"], unroll)
    elif cfg.family == "hybrid":
        glb, runs = hymba_layer_groups(cfg)
        swa_body = _maybe_remat(
            lambda c, bp: (_hybrid_block_train(bp, c, cfg, positions,
                                               cfg.attn_window), None), remat)
        g_body = _maybe_remat(
            lambda c, bp: (_hybrid_block_train(bp, c, cfg, positions, 0), None),
            remat)
        offset = 0
        for gi in range(len(runs)):
            n_run = len(runs[gi])
            if n_run:
                grp = jax.tree_util.tree_map(
                    lambda a: a[offset:offset + n_run], params["swa_blocks"])
                x, _ = _scan_layers(swa_body, x, grp, unroll)
                offset += n_run
            if gi < len(glb):
                gp = jax.tree_util.tree_map(lambda a: a[gi],
                                            params["global_blocks"])
                x, _ = g_body(x, gp)
    elif cfg.family == "vlm":
        def period_body(carry, pp):
            c, aux_c = carry

            def self_body(cc, bp):
                y, _ = _dense_block_train(bp, cc, cfg, positions, 0)
                return y, None

            c, _ = _scan_layers(_maybe_remat(self_body, remat), c,
                                pp["self"], unroll)
            c = _maybe_remat(
                lambda cc, bp: _cross_block_train(bp, cc, cfg, vis_embed),
                remat)(c, pp["cross"])
            return (c, aux_c), None

        (x, _), _ = _scan_layers(period_body, (x, 0.0), params["periods"],
                                 unroll)
    else:  # dense / moe / audio
        def body(carry, bp):
            c, a_aux, a_z = carry
            y, losses = _dense_block_train(bp, c, cfg, positions,
                                           cfg.attn_window)
            a_aux = a_aux + losses.get("moe_aux", 0.0)
            a_z = a_z + losses.get("moe_z", 0.0)
            return (y, a_aux, a_z), None

        (x, aux["moe_aux"], aux["moe_z"]), _ = _scan_layers(
            _maybe_remat(body, remat), (x, aux["moe_aux"], aux["moe_z"]),
            params["blocks"], unroll)

    x = apply_norm(params["final_norm"], x, cfg)
    if last_logits_only:
        x = x[:, -1:]
    logits = constrain(unembed(params["embed"], x, cfg),
                       ("batch", None, "tp"))
    return logits, aux


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

class DecodeCache(NamedTuple):
    """Family-polymorphic cache bundle (unused fields are None)."""
    kv: kvc.KVCache | None = None           # self-attn (stacked over layers)
    global_kv: kvc.KVCache | None = None    # hybrid global layers
    ssm: ssm_mod.SSMCache | None = None     # stacked over layers
    cross_k: jax.Array | None = None        # vlm (n_cross, B, Nv, K, hd)
    cross_v: jax.Array | None = None


def init_decode_cache(cfg, batch: int, max_t: int,
                      kv_dtype=jnp.bfloat16) -> DecodeCache:
    hd = cfg.resolved_head_dim if cfg.n_heads else 0
    if cfg.family == "ssm":
        c = ssm_mod.init_ssm_cache(cfg, batch)
        stk = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), c)
        return DecodeCache(ssm=ssm_mod.SSMCache(*stk))
    if cfg.family == "hybrid":
        glb, runs = hymba_layer_groups(cfg)
        n_swa = cfg.n_layers - len(glb)
        w = min(cfg.attn_window, max_t)
        swa_kv = kvc.init_kv_cache(n_swa, batch, w, cfg.n_kv_heads, hd,
                                   kv_dtype)
        g_kv = kvc.init_kv_cache(len(glb), batch, max_t, cfg.n_kv_heads, hd,
                                 kv_dtype)
        c = ssm_mod.init_ssm_cache(cfg, batch)
        stk = jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a[None], (cfg.n_layers,) + a.shape), c)
        return DecodeCache(kv=swa_kv, global_kv=g_kv,
                           ssm=ssm_mod.SSMCache(*stk))
    if cfg.family == "vlm":
        # cross layers keep no self-KV; cache covers the self layers only
        n_self = cfg.n_layers - len(cfg.cross_attn_layers)
        kv = kvc.init_kv_cache(n_self, batch, max_t, cfg.n_kv_heads, hd,
                               kv_dtype)
        return DecodeCache(kv=kv, cross_k=None, cross_v=None)
    kv = kvc.init_kv_cache(cfg.n_layers, batch, max_t, cfg.n_kv_heads, hd,
                           kv_dtype)
    return DecodeCache(kv=kv)


def _attn_decode(bp: Params, h: jax.Array, kv_slice, pos, cfg, *,
                 window: int = 0):
    """Project/write/attend for one layer; kv_slice = (k,v,ks,vs) (B,T,...)."""
    k_c, v_c, ks_c, vs_c = kv_slice
    B = h.shape[0]
    T = k_c.shape[1]
    positions = jnp.full((B, 1), pos, jnp.int32)
    q, k_new, v_new = attn.project_qkv(bp["attn"], h, h, cfg,
                                       positions=positions,
                                       rope=cfg.pos_embedding == "rope")
    slot = pos % T if window > 0 else pos
    k_c, v_c, ks_c, vs_c = kvc.cache_write(k_c, v_c, ks_c, vs_c,
                                           k_new, v_new, slot)
    k_full, v_full = kvc.cache_read(k_c, v_c, ks_c, vs_c, h.dtype)
    idx = jnp.arange(T)
    valid = (idx < jnp.minimum(pos + 1, T)) if window > 0 else (idx <= pos)
    o = attn.dense_attention(q, k_full, v_full,
                             valid[None, None, None, None, :])
    hd = cfg.resolved_head_dim
    out = o.reshape(B, 1, cfg.n_heads * hd) @ bp["attn"]["wo"].astype(h.dtype)
    return out, (k_c, v_c, ks_c, vs_c)


def _kv_xs(kv: kvc.KVCache):
    ks = kv.k_scale if kv.k_scale is not None else jnp.zeros(kv.k.shape[:1])
    vs = kv.v_scale if kv.v_scale is not None else jnp.zeros(kv.v.shape[:1])
    return (kv.k, kv.v, ks, vs)


def _kv_from_ys(ys, quantised: bool) -> kvc.KVCache:
    k, v, ks, vs = ys
    return kvc.KVCache(k=k, v=v, k_scale=ks if quantised else None,
                       v_scale=vs if quantised else None)


def decode_step(params: Params, cfg, cache: DecodeCache, pos: jax.Array,
                tokens: jax.Array | None = None,
                embeds: jax.Array | None = None,
                vis_embed: jax.Array | None = None,
                unroll: bool = False
                ) -> tuple[jax.Array, DecodeCache]:
    """One-token decode → (logits (B,1,V), cache')."""
    if embeds is not None:
        x = embeds.astype(cdtype(cfg))
    else:
        x = embed(params["embed"], tokens, cfg)
    B = x.shape[0]
    if cfg.pos_embedding == "sinusoidal":
        ppos = jnp.full((B, 1), pos, jnp.int32)
        x = x + sinusoidal_positions(ppos, cfg.d_model).astype(x.dtype)

    new_cache = cache
    if cfg.family == "ssm":
        def body(c, xs):
            bp, conv_c, st_c = xs
            h = apply_norm(bp["norm1"], c, cfg)
            y, sc = ssm_mod.apply_ssm_decode(
                bp["ssm"], h, ssm_mod.SSMCache(conv_c, st_c), cfg)
            return c + y, (sc.conv, sc.state)

        x, (conv_n, st_n) = _scan_layers(
            body, x, (params["blocks"], cache.ssm.conv, cache.ssm.state),
            unroll)
        new_cache = cache._replace(ssm=ssm_mod.SSMCache(conv_n, st_n))
    elif cfg.family == "hybrid":
        x, new_cache = _hybrid_decode(params, cfg, cache, pos, x, unroll)
    elif cfg.family == "vlm":
        x, new_cache = _vlm_decode(params, cfg, cache, pos, x, vis_embed,
                                   unroll)
    else:
        quant = cache.kv.quantised
        # the cache rides in the scan CARRY and is updated in place with
        # dynamic_update_index_in_dim: with buffer donation the whole
        # decode step then runs without a second cache-sized buffer —
        # restacking the cache through scan ys double-buffers it, which
        # at 32k-context/32B-model scale is 10.7 GB of HBM (§Perf cell 2)
        kxs = _kv_xs(cache.kv)

        def body(carry, xs):
            c, k_all, v_all, ks_all, vs_all = carry
            bp, i = xs
            sl = lambda a: jax.lax.dynamic_index_in_dim(a, i, 0,
                                                        keepdims=False)
            k_c, v_c = sl(k_all), sl(v_all)
            ks_c = sl(ks_all) if quant else None
            vs_c = sl(vs_all) if quant else None
            h = apply_norm(bp["norm1"], c, cfg)
            a, kv_new = _attn_decode(bp, h, (k_c, v_c, ks_c, vs_c),
                                     pos, cfg, window=cfg.attn_window)
            c = c + a
            h = apply_norm(bp["norm2"], c, cfg)
            if "moe" in bp:
                m, _ = moe_mod.apply_moe(bp["moe"], h.reshape(1, B, -1), cfg)
                m = m.reshape(B, 1, -1)
            else:
                m = apply_mlp(bp["mlp"], h, cfg)
            wr = lambda a, new: jax.lax.dynamic_update_index_in_dim(
                a, new.astype(a.dtype), i, 0)
            k_all = wr(k_all, kv_new[0])
            v_all = wr(v_all, kv_new[1])
            if quant:
                ks_all = wr(ks_all, kv_new[2])
                vs_all = wr(vs_all, kv_new[3])
            return (c + m, k_all, v_all, ks_all, vs_all), None

        idx = jnp.arange(cfg.n_layers)
        (x, k_all, v_all, ks_all, vs_all), _ = _scan_layers(
            body, (x,) + kxs, (params["blocks"], idx), unroll)
        new_cache = cache._replace(
            kv=_kv_from_ys((k_all, v_all, ks_all, vs_all), quant))

    x = apply_norm(params["final_norm"], x, cfg)
    return unembed(params["embed"], x, cfg), new_cache


def _hybrid_decode(params, cfg, cache: DecodeCache, pos, x,
                   unroll: bool = False):
    glb, runs = hymba_layer_groups(cfg)
    quant = cache.kv.quantised

    def make_body(window):
        def body(c, xs):
            bp, k_c, v_c, ks_c, vs_c, conv_c, st_c = xs
            h = apply_norm(bp["norm1"], c, cfg)
            a, kv_new = _attn_decode(bp, h, (k_c, v_c,
                                             ks_c if quant else None,
                                             vs_c if quant else None),
                                     pos, cfg, window=window)
            s, sc = ssm_mod.apply_ssm_decode(
                bp["ssm"], h, ssm_mod.SSMCache(conv_c, st_c), cfg)
            c = c + 0.5 * (apply_norm(bp["norm_attn"], a, cfg)
                           + apply_norm(bp["norm_ssm"], s, cfg))
            h2 = apply_norm(bp["norm2"], c, cfg)
            c = c + apply_mlp(bp["mlp"], h2, cfg)
            kv_out = kv_new if quant else (kv_new[0], kv_new[1],
                                           jnp.zeros(()), jnp.zeros(()))
            return c, kv_out + (sc.conv, sc.state)
        return body

    swa_body = make_body(cfg.attn_window)
    g_body = make_body(0)
    # ssm caches are stacked over ALL layers; swa kv over swa layers only
    swa_ids = [i for i in range(cfg.n_layers) if i not in glb]
    ssm_swa = jax.tree_util.tree_map(lambda a: a[jnp.asarray(swa_ids, jnp.int32)],
                                     cache.ssm)
    ssm_glb = jax.tree_util.tree_map(lambda a: a[jnp.asarray(glb, jnp.int32)], cache.ssm)

    new_swa_kv, new_g_kv, new_ssm_swa, new_ssm_glb = [], [], [], []
    offset = 0
    for gi in range(len(runs)):
        n_run = len(runs[gi])
        if n_run:
            sl = lambda a: a[offset:offset + n_run]
            grp_p = jax.tree_util.tree_map(sl, params["swa_blocks"])
            grp_kv = tuple(sl(a) for a in _kv_xs(cache.kv))
            grp_ssm = jax.tree_util.tree_map(sl, ssm_swa)
            xs = (grp_p,) + grp_kv + (grp_ssm.conv, grp_ssm.state)
            x, ys = _scan_layers(swa_body, x, xs, unroll)
            new_swa_kv.append(ys[:4])
            new_ssm_swa.append(ys[4:])
            offset += n_run
        if gi < len(glb):
            gp = jax.tree_util.tree_map(lambda a: a[gi], params["global_blocks"])
            g_kv = tuple(a[gi] for a in _kv_xs(cache.global_kv))
            g_ssm = jax.tree_util.tree_map(lambda a: a[gi], ssm_glb)
            xs_g = (gp,) + g_kv + (g_ssm.conv, g_ssm.state)
            x, ys_g = g_body(x, xs_g)
            new_g_kv.append(tuple(a[None] for a in ys_g[:4]))
            new_ssm_glb.append(tuple(a[None] for a in ys_g[4:]))

    cat = lambda parts: tuple(jnp.concatenate([p[i] for p in parts], axis=0)
                              for i in range(len(parts[0])))
    # degenerate layer mixes (e.g. the extrapolation's swa-only reduced
    # configs) leave one group empty — keep that cache side unchanged
    conv_all = jnp.zeros_like(cache.ssm.conv)
    state_all = jnp.zeros_like(cache.ssm.state)
    new_kv, new_gkv = cache.kv, cache.global_kv
    if new_swa_kv:
        swa_kv = cat(new_swa_kv)
        ssm_s = cat(new_ssm_swa)
        conv_all = conv_all.at[jnp.asarray(swa_ids, jnp.int32)].set(ssm_s[0])
        state_all = state_all.at[jnp.asarray(swa_ids, jnp.int32)].set(ssm_s[1])
        new_kv = _kv_from_ys(swa_kv, quant)
    if new_g_kv:
        g_kv = cat(new_g_kv)
        ssm_g = cat(new_ssm_glb)
        conv_all = conv_all.at[jnp.asarray(glb, jnp.int32)].set(ssm_g[0])
        state_all = state_all.at[jnp.asarray(glb, jnp.int32)].set(ssm_g[1])
        new_gkv = _kv_from_ys(g_kv, quant)
    new_cache = cache._replace(
        kv=new_kv, global_kv=new_gkv,
        ssm=ssm_mod.SSMCache(conv_all, state_all))
    return x, new_cache


def precompute_cross_kv(params, cfg, vis_embed):
    """(n_cross, B, Nv, K, hd) K/V from the vision stub, once per request."""
    def one(pp):
        return attn.vision_kv(pp["cross"]["attn"], vis_embed, cfg)
    ks, vs = jax.vmap(one)(params["periods"])
    return ks, vs


def _vlm_decode(params, cfg, cache: DecodeCache, pos, x, vis_embed,
                unroll: bool = False):
    n_cross = len(cfg.cross_attn_layers)
    period = cfg.n_layers // n_cross
    quant = cache.kv.quantised
    if cache.cross_k is None:
        cross_k, cross_v = precompute_cross_kv(params, cfg, vis_embed)
    else:
        cross_k, cross_v = cache.cross_k, cache.cross_v

    # reshape the layer-stacked kv cache into periods
    def to_periods(a):
        return a.reshape((n_cross, period - 1) + a.shape[1:]) \
            if a.ndim > 1 else a
    kv_xs = tuple(a.reshape((n_cross, period - 1) + a.shape[1:])
                  for a in _kv_xs(cache.kv))

    def period_body(c, xs):
        pp, pk, pv, pks, pvs, ck, cv = xs

        def self_body(cc, s_xs):
            bp, k_c, v_c, ks_c, vs_c = s_xs
            h = apply_norm(bp["norm1"], cc, cfg)
            a, kv_new = _attn_decode(bp, h, (k_c, v_c,
                                             ks_c if quant else None,
                                             vs_c if quant else None),
                                     pos, cfg, window=0)
            cc = cc + a
            h = apply_norm(bp["norm2"], cc, cfg)
            cc = cc + apply_mlp(bp["mlp"], h, cfg)
            kv_out = kv_new if quant else (kv_new[0], kv_new[1],
                                           jnp.zeros(()), jnp.zeros(()))
            return cc, kv_out

        c, ys = _scan_layers(self_body, c, (pp["self"], pk, pv, pks, pvs),
                             unroll)
        # cross block (static K/V, no cache update)
        bp = pp["cross"]
        h = apply_norm(bp["norm1"], c, cfg)
        c = c + attn.cross_attention(bp["attn"], h, (ck, cv), cfg)
        h = apply_norm(bp["norm2"], c, cfg)
        c = c + apply_mlp(bp["mlp"], h, cfg)
        return c, ys

    x, ys = _scan_layers(period_body, x,
                         (params["periods"],) + kv_xs + (cross_k, cross_v),
                         unroll)
    flat = tuple(a.reshape((n_cross * (period - 1),) + a.shape[2:])
                 for a in ys)
    new_cache = cache._replace(kv=_kv_from_ys(flat, quant),
                               cross_k=cross_k, cross_v=cross_v)
    return x, new_cache
