"""The paper's three validation networks (§IV-C, Table II):

  * 2-layer SNN   — LIF neurons, fully connected, MNIST-class data
  * 6-layer DCSNN — Izhikevich neurons, conv stack, Fashion-MNIST-class data
  * 5-layer CSNN  — LIF neurons, 1-D conv stack, motor-fault time series

All layers learn with a selectable learning rule from the
``repro.plasticity`` registry ('itp' / 'itp_nocomp' history rules, 'exact'
/ 'linear' / 'imstdp' counter rules), sharing one protocol so the Table II
*parity* comparison is apples-to-apples.  Convolutional STDP applies the
pair-based rule per (patch-pixel → output-neuron) synapse, accumulated over
spatial positions at the patch level (the dense layer is the 1×1 special
case): every rule × backend cell dispatches through the plasticity apply
layer (``repro.plasticity.apply`` — conv layers via ``UpdatePlan.
conv_delta``, fc layers via ``UpdatePlan.fc_delta``), which routes to the
rule's im2col-fused kernel package (``repro.kernels.itp_stdp_conv`` for
the history rules, ``repro.kernels.itp_counter`` for the counter rules),
its dense engine kernel, its event-driven path, or its pure-jnp oracle —
so the full rule × backend matrix runs end-to-end at the network level.  Readout is a deterministic ridge
regression on time-averaged spike counts — identical across rules, so
accuracy differences isolate the learning rule.

For the history rules, weight-update magnitudes come from the same
bitplane histories as the learning engine: ``itp`` reads the history
against e^(-k/τ) ≡ 2^(-k/(τ·ln2)) (identical by eq. 18 — the paper's
equivalence), ``itp_nocomp`` against the raw po2 place values 2^(-k/τ).
The counter rule ``exact`` evaluates e^(-Δt/τ) from last-spike counters —
trajectory-identical to compensated ``itp`` on the integer grid, which is
exactly the paper's equivalence claim.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro import plasticity
from repro.core.lif import (IzhikevichParams, LIFParams, izhikevich_init,
                            izhikevich_step, lif_init, lif_step)
from repro.core.stdp import STDPParams
from repro.kernels.dispatch import im2col_1d, im2col_2d, resolve_packed


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SNNLayerSpec:
    kind: str                      # "fc" | "conv2d" | "conv1d" | "pool2d" | "pool1d"
    out_features: int = 0          # fc width / conv out-channels
    kernel: int = 3
    stride: int = 1
    pool: int = 2


@dataclasses.dataclass(frozen=True)
class SNNConfig:
    name: str
    input_shape: tuple            # (H, W, C) images / (L, C) series / (N,) flat
    layers: tuple                 # tuple[SNNLayerSpec, ...]
    neuron: str = "lif"           # lif | izhikevich
    rule: str = "itp"             # plasticity.rule_names()
    depth: int = 7                # spike-history depth (§IV-B)
    pairing: str = "nearest"
    eta: float = 1.0 / 64.0
    gain: float = 4.0             # synaptic gain / fan-in normalisation
    izhi_gain: float = 20.0       # current scale into the Izhikevich model
    w_bits: int = 8
    quantise: bool = True
    backend: str = "reference"    # reference | fused | fused_interpret
                                  # | sparse (event-driven)
    max_events: int | None = None  # sparse backend: static event-list cap
                                  # per side and per sample (None = popu-
                                  # lation size; excess events beyond the
                                  # cap are deterministically the highest-
                                  # indexed and are dropped)
    packed_history: bool = True   # fused* datapaths read packed uint8
                                  # register words (one byte per neuron /
                                  # patch element); False keeps the unpacked
                                  # bitplane kernel operands (the oracle)
    inhibition: float = 0.0       # lateral inhibition strength (2-layer SNN)
    hard_wta: bool = False        # hard winner-take-all: per sample (and
                                  # spatial position) only the most-driven
                                  # super-threshold neuron fires; the
                                  # suppressed ones are shunt-inhibited
                                  # (membrane reset).  Stacks on top of the
                                  # soft `inhibition` current.
    theta_plus: float = 0.0       # adaptive-threshold homeostasis: per-
                                  # neuron threshold increment per spike
                                  # (0 disables; θ is per output channel,
                                  # persists across sample resets)
    theta_tau: float = 200.0      # θ decay time constant (steps)
    stdp: STDPParams = dataclasses.field(default_factory=STDPParams)
    lif: LIFParams = dataclasses.field(
        default_factory=lambda: LIFParams(tau=2.0, v_th=0.6))
    izhi: IzhikevichParams = dataclasses.field(default_factory=IzhikevichParams)

    def __post_init__(self):
        # config-construction-time validation of the rule × backend cell —
        # the single shared validator (plasticity.validate_update_config)
        # keeps messages and valid-option listings identical to
        # EngineConfig's — plus the SNN-only homeostasis knobs
        plasticity.validate_update_config(
            rule=self.rule, backend=self.backend, pairing=self.pairing,
            max_events=self.max_events)
        if self.theta_plus < 0.0:
            raise ValueError(
                f"theta_plus must be >= 0 (0 disables homeostasis), "
                f"got {self.theta_plus}")
        if self.theta_tau <= 0.0:
            raise ValueError(
                f"theta_tau must be a positive decay time constant "
                f"(steps), got {self.theta_tau}")

    def learning_rule(self) -> plasticity.LearningRule:
        return plasticity.get_rule(self.rule)

    @property
    def compensate(self) -> bool:
        # 'exact' and compensated 'itp' are numerically identical on the
        # integer delay grid (paper eq. 18) — both read e^(-k/τ);
        # 'itp_nocomp' pins the raw po2 read via its rule override.
        rc = self.learning_rule().compensate
        return True if rc is None else rc

    def use_packed_history(self) -> bool:
        """Packed uint8 words hold depth <= 8 only; deeper histories keep
        the unpacked bitplane kernel operands (bit-identical, so packing
        is purely a bandwidth optimisation — never a trace-time failure).
        Resolution is owned by ``repro.kernels.dispatch.resolve_packed``."""
        return resolve_packed(self.packed_history, depth=self.depth)


# The paper's three networks -------------------------------------------------

def mnist_2layer(rule: str = "itp", n_hidden: int = 100, **kw) -> SNNConfig:
    """2-layer fully connected SNN (LIF) for MNIST-class images."""
    return SNNConfig(
        name="2layer-snn",
        input_shape=(28, 28, 1),
        layers=(SNNLayerSpec("fc", out_features=n_hidden),),
        neuron="lif", rule=rule, inhibition=0.1, gain=1.2, **kw)


def fmnist_dcsnn(rule: str = "itp", **kw) -> SNNConfig:
    """6-layer deep convolutional SNN (Izhikevich) for Fashion-MNIST-class
    images: conv-pool-conv-pool-fc-readout (readout is external)."""
    return SNNConfig(
        name="6layer-dcsnn",
        input_shape=(28, 28, 1),
        layers=(
            SNNLayerSpec("conv2d", out_features=12, kernel=5),
            SNNLayerSpec("pool2d", pool=2),
            SNNLayerSpec("conv2d", out_features=24, kernel=3),
            SNNLayerSpec("pool2d", pool=2),
            SNNLayerSpec("fc", out_features=128),
        ),
        neuron="izhikevich", rule=rule, gain=1.2,
        izhi=IzhikevichParams(dt=0.5), **kw)


def fault_csnn(rule: str = "itp", length: int = 512, channels: int = 2,
               **kw) -> SNNConfig:
    """5-layer 1-D convolutional SNN (LIF) for motor-fault time series."""
    return SNNConfig(
        name="5layer-csnn",
        input_shape=(length, channels),
        layers=(
            SNNLayerSpec("conv1d", out_features=8, kernel=7, stride=2),
            SNNLayerSpec("pool1d", pool=2),
            SNNLayerSpec("conv1d", out_features=16, kernel=5, stride=2),
            SNNLayerSpec("pool1d", pool=2),
            SNNLayerSpec("fc", out_features=64),
        ),
        neuron="lif", rule=rule, gain=1.2,
        lif=LIFParams(tau=2.0, v_th=0.8), **kw)


PAPER_NETWORKS = {
    "2layer-snn": mnist_2layer,
    "6layer-dcsnn": fmnist_dcsnn,
    "5layer-csnn": fault_csnn,
}


# ---------------------------------------------------------------------------
# Layer shape inference
# ---------------------------------------------------------------------------

def _layer_shapes(cfg: SNNConfig) -> list[tuple]:
    """Output feature shape after each layer (excluding batch)."""
    shape = tuple(cfg.input_shape)
    out = []
    for spec in cfg.layers:
        if spec.kind == "fc":
            shape = (spec.out_features,)
        elif spec.kind == "conv2d":
            h, w, _ = shape
            ho = (h - spec.kernel) // spec.stride + 1
            wo = (w - spec.kernel) // spec.stride + 1
            shape = (ho, wo, spec.out_features)
        elif spec.kind == "conv1d":
            l, _ = shape
            lo = (l - spec.kernel) // spec.stride + 1
            shape = (lo, spec.out_features)
        elif spec.kind == "pool2d":
            h, w, c = shape
            shape = (h // spec.pool, w // spec.pool, c)
        elif spec.kind == "pool1d":
            l, c = shape
            shape = (l // spec.pool, c)
        else:
            raise ValueError(spec.kind)
        out.append(shape)
    return out


def feature_size(cfg: SNNConfig) -> int:
    last = _layer_shapes(cfg)[-1]
    n = 1
    for d in last:
        n *= d
    return n


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

class LayerState(NamedTuple):
    neurons: Any                 # LIFState | IzhikevichState | None (pool)
    pre_hist: Any                # rule timing state (histories / counters)
    post_hist: Any
    theta: Any = None            # adaptive-threshold homeostasis state:
                                 # (out_features,) f32 per output channel
                                 # (None for pool layers).  Persists across
                                 # reset_dynamics — it is the slow
                                 # homeostatic variable, not fast dynamics.


class SNNState(NamedTuple):
    weights: tuple               # per learnable layer: (fan_in, out) f32
    layers: tuple                # per layer: LayerState


def _neuron_init(cfg: SNNConfig, shape) -> Any:
    if cfg.neuron == "izhikevich":
        return izhikevich_init(shape, cfg.izhi)
    return lif_init(shape, cfg.lif)


def _fan_in(spec: SNNLayerSpec, in_shape: tuple) -> int:
    if spec.kind == "fc":
        n = 1
        for d in in_shape:
            n *= d
        return n
    if spec.kind == "conv2d":
        return spec.kernel * spec.kernel * in_shape[-1]
    if spec.kind == "conv1d":
        return spec.kernel * in_shape[-1]
    return 0


def init_snn(key: jax.Array, cfg: SNNConfig, batch: int) -> SNNState:
    shapes = _layer_shapes(cfg)
    weights, states = [], []
    in_shape = tuple(cfg.input_shape)
    for spec, out_shape in zip(cfg.layers, shapes):
        if spec.kind.startswith("pool"):
            states.append(LayerState(None, None, None))
        else:
            key, sub = jax.random.split(key)
            fi = _fan_in(spec, in_shape)
            w = jax.random.uniform(sub, (fi, spec.out_features),
                                   minval=0.2, maxval=0.8)
            weights.append(w.astype(jnp.float32))
            rule = cfg.learning_rule()
            n_pre = batch * int(jnp.prod(jnp.asarray(in_shape)))
            n_post = batch * int(jnp.prod(jnp.asarray(out_shape)))
            states.append(LayerState(
                neurons=_neuron_init(cfg, (batch,) + out_shape),
                pre_hist=rule.init_state(n_pre, cfg.depth),
                post_hist=rule.init_state(n_post, cfg.depth),
                theta=jnp.zeros((spec.out_features,), jnp.float32),
            ))
        in_shape = out_shape
    return SNNState(weights=tuple(weights), layers=tuple(states))


def _quantise(w: jax.Array, cfg: SNNConfig) -> jax.Array:
    if not cfg.quantise:
        return w
    levels = (1 << (cfg.w_bits - 1)) - 1
    return jnp.round(w * levels) / levels


# ---------------------------------------------------------------------------
# Layer steps
# ---------------------------------------------------------------------------

def _learnable_step(spec: SNNLayerSpec, cfg: SNNConfig, w: jax.Array,
                    st: LayerState, spikes_in: jax.Array,
                    train: bool) -> tuple[jax.Array, LayerState, jax.Array]:
    """One step of an fc/conv STDP layer.

    spikes_in: (B, *in_shape) {0,1}.  Returns (w', state', spikes_out).
    """
    B = spikes_in.shape[0]
    s_in = spikes_in.astype(jnp.float32)

    # --- patches + synaptic accumulation --------------------------------
    if spec.kind == "fc":
        patches = s_in.reshape(B, 1, -1)                   # (B, P=1, fan_in)
    elif spec.kind == "conv2d":
        p = im2col_2d(s_in, spec.kernel, spec.stride)      # (B,Ho,Wo,K)
        patches = p.reshape(B, -1, p.shape[-1])
        out_hw = p.shape[1:3]
    else:                                                   # conv1d
        p = im2col_1d(s_in, spec.kernel, spec.stride)
        patches = p.reshape(B, -1, p.shape[-1])
        out_l = p.shape[1]
    # activity-normalised accumulation: scale by the *population mean*
    # active-synapse count (a per-step scalar), which keeps the layer's
    # operating point invariant to width/sparsity (synaptic-scaling
    # homeostasis) while preserving within-step selectivity — patches
    # with stronger weighted input still drive proportionally more
    # current, unlike a per-patch normaliser which flattens selectivity
    act_mean = jnp.mean(jnp.sum(patches, axis=-1))          # scalar
    i_in = cfg.gain * jnp.einsum("bpk,kc->bpc", patches, w) \
        / jnp.maximum(act_mean, 1.0)

    # --- lateral inhibition (2-layer SNN soft WTA) -----------------------
    if cfg.inhibition > 0.0 and st.post_hist is not None:
        prev = cfg.learning_rule().last_spikes(st.post_hist)
        prev = prev.reshape(i_in.shape[0], -1).reshape(i_in.shape)
        total = jnp.sum(prev, axis=-1, keepdims=True)
        i_in = i_in - cfg.inhibition * (total - prev)

    # --- neuron dynamics --------------------------------------------------
    if spec.kind == "fc":
        out_shape = (B, w.shape[1])
    elif spec.kind == "conv2d":
        out_shape = (B,) + out_hw + (w.shape[1],)
    else:
        out_shape = (B, out_l, w.shape[1])
    i_flat = i_in.reshape(out_shape)
    # adaptive-threshold homeostasis: the per-output-channel θ raises each
    # neuron's effective threshold, equalising firing rates so no subset of
    # neurons captures every input (θ stays all-zero when theta_plus == 0,
    # leaving the classic fixed-threshold trajectories untouched)
    theta = st.theta if st.theta is not None else 0.0
    if cfg.neuron == "izhikevich":
        neurons, spikes_out = izhikevich_step(
            st.neurons, cfg.izhi_gain * i_flat, cfg.izhi, v_th_offset=theta)
    else:
        neurons, spikes_out = lif_step(st.neurons, i_flat, cfg.lif,
                                       v_th_offset=theta)
    if cfg.hard_wta:
        # hard WTA on top of the soft inhibition current: per sample (and
        # spatial position) only the most-driven super-threshold neuron
        # keeps its spike; the suppressed ones were already membrane-reset
        # in the neuron step (shunt-inhibition semantics)
        drive = jnp.where(spikes_out, i_flat, -jnp.inf)
        winner = jnp.argmax(drive, axis=-1)[..., None]
        spikes_out = spikes_out & (jnp.arange(i_flat.shape[-1]) == winner)
    s_out = spikes_out.astype(jnp.float32)

    # --- STDP update (dispatched through the plasticity apply layer) ------
    # One UpdatePlan owns backend resolution, packed-readout selection and
    # the fused / event-driven / reference delta variants for both layer
    # kinds (repro.plasticity.apply); the layer keeps only model-level
    # policy — batch/patch-position normalisation, the fixed [0, 1] weight
    # window, and quantisation.
    rule = cfg.learning_rule()
    if train:
        plan = plasticity.make_plan(cfg)
        if spec.kind != "fc":
            dw = plan.conv_delta(st.pre_hist, st.post_hist, patches, s_out,
                                 in_shape=spikes_in.shape[1:],
                                 kind=spec.kind, kernel=spec.kernel,
                                 stride=spec.stride)
        else:
            dw = plan.fc_delta(st.pre_hist, st.post_hist, s_in, s_out)
        denom = float(B * patches.shape[1])            # P = 1 for fc
        w = jnp.clip(w + cfg.eta * dw / denom, 0.0, 1.0)
        w = _quantise(w, cfg)

    # --- homeostasis θ update (training only; frozen during eval) ---------
    theta_new = st.theta
    if train and cfg.theta_plus > 0.0 and st.theta is not None:
        # exponential decay towards 0 plus an increment proportional to
        # each channel's firing rate this step (mean over batch + spatial
        # positions, so the operating point is batch-size invariant)
        rate = s_out.reshape(-1, s_out.shape[-1]).mean(axis=0)
        theta_new = st.theta * jnp.exp(-1.0 / cfg.theta_tau) \
            + cfg.theta_plus * rate

    # --- record new spikes (history shift-in / counter reset) ------------
    st = LayerState(
        neurons=neurons,
        pre_hist=rule.step(st.pre_hist, s_in.reshape(-1), depth=cfg.depth),
        post_hist=rule.step(st.post_hist, s_out.reshape(-1), depth=cfg.depth),
        theta=theta_new,
    )
    return w, st, spikes_out


def _pool_step(spec: SNNLayerSpec, spikes_in: jax.Array) -> jax.Array:
    """Spike OR-pooling (any spike in the window fires the pooled unit)."""
    s = spikes_in.astype(jnp.float32)
    if spec.kind == "pool2d":
        B, H, W, C = s.shape
        p = spec.pool
        s = s[:, :H // p * p, :W // p * p]
        s = s.reshape(B, H // p, p, W // p, p, C).max(axis=(2, 4))
    else:
        B, L, C = s.shape
        p = spec.pool
        s = s[:, :L // p * p]
        s = s.reshape(B, L // p, p, C).max(axis=2)
    return s > 0.5


# ---------------------------------------------------------------------------
# Network step / run
# ---------------------------------------------------------------------------

def snn_step(state: SNNState, spikes_in: jax.Array, cfg: SNNConfig,
             *, train: bool = True) -> tuple[SNNState, jax.Array]:
    """One simulation step through the whole stack; returns last-layer spikes."""
    new_w, new_l = [], []
    wi = 0
    s = spikes_in
    for spec, lst in zip(cfg.layers, state.layers):
        if spec.kind.startswith("pool"):
            s = _pool_step(spec, s)
            new_l.append(lst)
        else:
            w, lst2, s = _learnable_step(spec, cfg, state.weights[wi], lst, s,
                                         train)
            new_w.append(w)
            new_l.append(lst2)
            wi += 1
    return SNNState(weights=tuple(new_w), layers=tuple(new_l)), s


@partial(jax.jit, static_argnames=("cfg", "train"))
def run_snn(state: SNNState, raster: jax.Array, cfg: SNNConfig,
            *, train: bool = True) -> tuple[SNNState, jax.Array]:
    """Scan over a (T, B, *input_shape) raster.

    Returns (state', spike counts of the last layer (B, feature_size)).
    """
    T, B = raster.shape[:2]
    x = raster.reshape((T, B) + tuple(cfg.input_shape))

    def step(st, xt):
        st2, s_out = snn_step(st, xt, cfg, train=train)
        return st2, s_out.reshape(B, -1).astype(jnp.float32)

    state, outs = jax.lax.scan(step, state, x)
    return state, outs.sum(axis=0)


def reset_dynamics(state: SNNState, cfg: SNNConfig, batch: int) -> SNNState:
    """Zero neuron states + histories between samples; keep learned weights
    AND the adaptive thresholds θ — homeostasis is the slow variable that
    must integrate firing rates across samples, not within one raster."""
    fresh = init_snn(jax.random.PRNGKey(0), cfg, batch)
    layers = tuple(
        f._replace(theta=old.theta) if old.theta is not None else f
        for f, old in zip(fresh.layers, state.layers))
    return SNNState(weights=state.weights, layers=layers)


# ---------------------------------------------------------------------------
# Readout: ridge regression on spike counts (shared protocol, Table II)
# ---------------------------------------------------------------------------

def fit_readout(features: jax.Array, labels: jax.Array, n_classes: int,
                l2: float = 1e-3) -> jax.Array:
    """Closed-form ridge readout W: features (N, F) → one-hot labels."""
    X = jnp.asarray(features, jnp.float32)
    X = X / jnp.maximum(X.max(), 1.0)
    X = jnp.concatenate([X, jnp.ones((X.shape[0], 1))], axis=1)
    Y = jax.nn.one_hot(labels, n_classes)
    A = X.T @ X + l2 * jnp.eye(X.shape[1])
    return jnp.linalg.solve(A, X.T @ Y)


def readout_accuracy(W: jax.Array, features: jax.Array,
                     labels: jax.Array) -> float:
    X = jnp.asarray(features, jnp.float32)
    X = X / jnp.maximum(X.max(), 1.0)
    X = jnp.concatenate([X, jnp.ones((X.shape[0], 1))], axis=1)
    pred = jnp.argmax(X @ W, axis=-1)
    return float(jnp.mean(pred == labels))
