"""Bitplane spike-history storage — the TPU adaptation of the paper's
shift-register array.

On the FPGA/ASIC the spike history of neuron *i* is a ``depth``-bit shift
register; every step shifts in the new spike bit.  On TPU, shifting data is
wasted bandwidth, so we keep a **ring buffer of bitplanes**:

    ``planes`` : uint8[depth, N]   planes[s, i] = spike of neuron i at slot s
    ``head``   : int32             slot holding the *most recent* step

"Shift" = overwrite slot ``(head+1) % depth`` and bump ``head`` — O(N) write,
no movement of the other ``depth-1`` planes.  Reading the logical register
(k=0 most-recent … k=depth-1 oldest) is a gather along the slot axis with
index ``(head - k) % depth``; the paper's fixed-point read (eq. 2 / Fig. 3)
becomes a dot of that gathered view with the constant po2 vector, and the
MSB-priority-encode (Fig. 11) an argmax over k.

A packed representation (``uint8`` word per neuron, depth ≤ 8) is also
provided: it is bit-exact with the register picture in the paper and is the
storage format used by the Pallas kernel.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class SpikeHistory(NamedTuple):
    """Ring-buffer bitplane history for N neurons."""

    planes: jax.Array  # uint8[depth, N]
    head: jax.Array    # int32 scalar, slot index of most recent step

    @property
    def depth(self) -> int:
        return self.planes.shape[0]

    @property
    def n(self) -> int:
        return self.planes.shape[1]


def init_history(n: int, depth: int = 7, dtype=jnp.uint8) -> SpikeHistory:
    return SpikeHistory(planes=jnp.zeros((depth, n), dtype),
                        head=jnp.asarray(depth - 1, jnp.int32))


def push(h: SpikeHistory, spikes: jax.Array) -> SpikeHistory:
    """Record the current step's spikes (the hardware 'shift-in')."""
    new_head = (h.head + 1) % h.depth
    planes = jax.lax.dynamic_update_index_in_dim(
        h.planes, spikes.astype(h.planes.dtype)[None, :], new_head, axis=0
    )
    return SpikeHistory(planes=planes, head=new_head.astype(jnp.int32))


def as_register(h: SpikeHistory) -> jax.Array:
    """Materialise the logical registers: (N, depth), k=0 most recent.

    Equivalent to the shift-register contents in paper Figs. 2/3.
    """
    k = jnp.arange(h.depth)
    slots = (h.head - k) % h.depth          # (depth,)
    return h.planes[slots, :].T              # (N, depth)


def registers_depth_major(h: SpikeHistory) -> jax.Array:
    """(depth, N) logical registers, k=0 row = most recent — no transpose.

    ``roll`` of the reversed planes instead of a gather+transpose: XLA
    lowers it to two static slices, keeping the hot engine path free of
    the (N, depth) relayout that dominated the first profile (§Perf log).
    out[k] = planes[(head - k) % depth].
    """
    rev = h.planes[::-1]                     # rev[j] = planes[depth-1-j]
    return jnp.roll(rev, h.head + 1, axis=0)


def latest(h: SpikeHistory) -> jax.Array:
    """The most recent spike bit per neuron: ``(N,)`` uint8.

    ``planes[head]`` directly — the k=0 column of :func:`as_register`
    without materialising the (N, depth) gather+transpose (hot path of the
    lateral-inhibition read, see ``repro.plasticity.rules``).
    """
    return h.planes[h.head]


def pack_bitplanes(bits: jax.Array) -> jax.Array:
    """Pack depth-major ``(depth, ...)`` {0,1} bitplanes into uint8 words.

    The single owner of the MSB-first word layout (register slot k → word
    bit ``7 - k``): :func:`pack_words`, the benchmarks, and the tests all
    derive words through here, so the format lives in exactly one place.
    """
    depth = bits.shape[0]
    if depth > 8:
        raise ValueError("pack_bitplanes supports depth <= 8")
    shifts = jnp.arange(7, 7 - depth, -1, dtype=jnp.uint8)  # MSB-first
    shifts = shifts.reshape((depth,) + (1,) * (bits.ndim - 1))
    return jnp.sum(bits.astype(jnp.uint8) << shifts, axis=0, dtype=jnp.uint8)


def pack_words(h: SpikeHistory) -> jax.Array:
    """Pack each neuron's register into a uint8 word, MSB = most recent.

    This is byte-for-byte the register file of the hardware design (depth≤8;
    one spare low bit when depth==7, matching the paper's 8-bit datapath
    with a sign bit reserved in the weight word, not here).  Built from the
    depth-major register view so the hot packed readout never materialises
    the (N, depth) relayout.
    """
    if h.depth > 8:
        raise ValueError("pack_words supports depth <= 8")
    return pack_bitplanes(registers_depth_major(h))


def unpack_words(words: jax.Array, depth: int) -> jax.Array:
    """Inverse of :func:`pack_words` → (N, depth) bitplanes, k=0 most recent."""
    if depth > 8:
        raise ValueError("unpack_words supports depth <= 8")
    shifts = jnp.arange(7, 7 - depth, -1, dtype=jnp.uint8)
    return ((words[..., None] >> shifts) & jnp.uint8(1)).astype(jnp.uint8)


def from_words(words: jax.Array, depth: int) -> SpikeHistory:
    """Rebuild a ring buffer from packed words: inverse of :func:`pack_words`.

    The head position is not stored in the word — it doesn't need to be:
    every readout (:func:`registers_depth_major`, :func:`as_register`,
    :func:`latest`, :func:`pack_words`) is rotation-invariant, so any
    (planes, head) pair with the same logical registers continues the
    trajectory bit-identically.  The canonical choice here is
    ``head = depth - 1`` (the :func:`init_history` layout): the k-th
    newest register lands in plane ``depth - 1 - k`` and the next
    :func:`push` overwrites plane 0 — the oldest slot, exactly as the
    original buffer would have.  This is the deserialization half of the
    serving layer's per-session "plasticity cache" (``repro.serve``).
    """
    regs = unpack_words(words, depth).T              # (depth, N), k=0 newest
    return SpikeHistory(planes=regs[::-1],
                        head=jnp.asarray(depth - 1, jnp.int32))


def fixed_point_value(words: jax.Array, depth: int) -> jax.Array:
    """Read a packed history word as the paper's binary fraction (eq. 2).

    With one integer bit (the MSB, weight 2^0 = 128/128) the word value is
    Σ_k h[k]·2^(-k) — the all-to-all accumulation of eq. (2) for the raw
    (uncompensated, τ'=1) po2 read, i.e. ``a2a_delta_from_history`` with
    amplitude 1.  The /128 scale is **depth-independent**: :func:`pack_words`
    places k=0 at the MSB for every depth ≤ 8, so for depth < 8 the unused
    low bits are zero and contribute nothing — a depth-7 word reads the same
    Σ_{k<7} h[k]·2^(-k) as a depth-8 word with an empty oldest slot.  This
    is the place-value oracle the packed Pallas kernels are tested against
    (tests/test_history.py, tests/test_kernels.py).
    """
    del depth  # the place-value read is depth-independent once packed
    return words.astype(jnp.float32) / 128.0  # MSB has place value 2^0 = 128/128
