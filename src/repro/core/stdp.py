"""STDP weight-update rule family.

Implements the paper's rule hierarchy (eqs. 1, 15-20):

  * ``exact``        — original pair-based STDP, base-e exponential (eq. 17).
  * ``itp``          — Intrinsic-Timing Power-of-two STDP (eq. 20), the
                       paper's contribution. With ``compensate=True`` the
                       time constant is pre-multiplied by ln 2 (eq. 18),
                       making the rule *mathematically identical* to
                       ``exact``; without compensation it deviates by the
                       bounded error analysed in §IV-A.
  * ``linear``       — the PWL approximation of [24] (linear decay clipped
                       at the window edge), included as a baseline.
  * ``imstdp``       — the LUT-based implicit-timing rule of [23]: the
                       exponential is precomputed on the integer index grid
                       and looked up; included as a baseline.

All rules share one signature: ``rule(dt)`` maps the (possibly fractional)
pre/post timing difference ``dt = t_post - t_pre`` (already normalised by the
discretisation ``ΔT/τ`` where applicable — see :func:`normalise_dt`) to a
weight increment.  Positive ``dt`` → LTP (potentiation), negative → LTD.

Everything is pure JAX and vectorises over arbitrary leading axes.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp

LN2 = math.log(2.0)


@dataclasses.dataclass(frozen=True)
class STDPParams:
    """Parameters of the pair-based STDP window (paper eq. 1).

    ``a_plus``/``a_minus`` are the LTP/LTD amplitudes, ``tau_plus``/
    ``tau_minus`` the time constants *in units of the discrete step* ΔT
    (the paper folds ΔT into τ via eq. 16).
    """

    a_plus: float = 1.0
    a_minus: float = 1.125
    tau_plus: float = 4.0
    tau_minus: float = 4.0

    def compensated(self) -> "STDPParams":
        """τ' = τ·ln2 — the paper's error compensation (eq. 18).

        After compensation ``2^(-dt/τ') = e^(-dt/τ)`` exactly.
        """
        return dataclasses.replace(
            self, tau_plus=self.tau_plus * LN2, tau_minus=self.tau_minus * LN2
        )


# ---------------------------------------------------------------------------
# Rule definitions.  Each maps dt -> Δw elementwise.
# ---------------------------------------------------------------------------

def exact_stdp(dt: jax.Array, p: STDPParams) -> jax.Array:
    """Original STDP, base-e exponential (paper eq. 17)."""
    dt = jnp.asarray(dt, jnp.float32)
    ltp = p.a_plus * jnp.exp(-dt / p.tau_plus)
    ltd = -p.a_minus * jnp.exp(dt / p.tau_minus)
    return jnp.where(dt >= 0, ltp, ltd)


def itp_stdp(dt: jax.Array, p: STDPParams, *, compensate: bool = True) -> jax.Array:
    """ITP-STDP, base-2 exponential (paper eq. 20).

    ``compensate=True`` applies τ' = τ·ln2 first (eq. 18) which renders the
    rule identical to :func:`exact_stdp`.  ``compensate=False`` is the raw
    power-of-two rule whose deviation the paper bounds at 9.48 % RMSE.
    """
    if compensate:
        p = p.compensated()
    dt = jnp.asarray(dt, jnp.float32)
    ltp = p.a_plus * jnp.exp2(-dt / p.tau_plus)
    ltd = -p.a_minus * jnp.exp2(dt / p.tau_minus)
    return jnp.where(dt >= 0, ltp, ltd)


def linear_stdp(dt: jax.Array, p: STDPParams, *, window: float | None = None) -> jax.Array:
    """PWL baseline of [24]: linear decay to zero at the window edge.

    The line is matched to the exponential's value and integral-free slope at
    dt=0 (A, -A/τ), clipped at ``window`` (default 2τ where the line hits 0
    ... actually the A·(1-dt/(2τ)) form crosses zero at 2τ).
    """
    dt = jnp.asarray(dt, jnp.float32)
    wp = window if window is not None else 2.0 * p.tau_plus
    wm = window if window is not None else 2.0 * p.tau_minus
    ltp = p.a_plus * jnp.clip(1.0 - dt / wp, 0.0, 1.0)
    ltd = -p.a_minus * jnp.clip(1.0 + dt / wm, 0.0, 1.0)
    return jnp.where(dt >= 0, ltp, ltd)


def make_imstdp_lut(p: STDPParams, depth: int = 8) -> jax.Array:
    """Precomputed LUT of [23]: Δw per integer index difference.

    Index k ∈ [0, depth) holds LTP(k); index depth+k holds LTD(-k).
    """
    k = jnp.arange(depth, dtype=jnp.float32)
    ltp = p.a_plus * jnp.exp(-k / p.tau_plus)
    ltd = -p.a_minus * jnp.exp(-k / p.tau_minus)
    return jnp.concatenate([ltp, ltd])


def imstdp(dt: jax.Array, p: STDPParams, *, depth: int = 8) -> jax.Array:
    """ImSTDP baseline: quantise dt to the integer index grid and look up.

    The quantisation (floor of |dt|) is the uncompensated timing error the
    paper criticises in §I.
    """
    lut = make_imstdp_lut(p, depth)
    dt = jnp.asarray(dt, jnp.float32)
    k = jnp.clip(jnp.floor(jnp.abs(dt)).astype(jnp.int32), 0, depth - 1)
    idx = jnp.where(dt >= 0, k, depth + k)
    return lut[idx]


RULES: dict[str, Callable[..., jax.Array]] = {
    "exact": exact_stdp,
    "itp": itp_stdp,
    "itp_nocomp": partial(itp_stdp, compensate=False),
    "linear": linear_stdp,
    "imstdp": imstdp,
}


def get_rule(name: str) -> Callable[..., jax.Array]:
    try:
        return RULES[name]
    except KeyError as e:  # pragma: no cover - defensive
        raise ValueError(f"unknown STDP rule {name!r}; have {sorted(RULES)}") from e


# ---------------------------------------------------------------------------
# Power-of-two weight-update primitives on bitplane spike histories.
#
# These are the *intrinsic-timing* forms: the timing difference is never
# computed; the history register itself is the operand.  ``history`` has
# shape (..., depth) with element h[k] = 1 iff the neuron spiked k steps ago
# (k=0 is the current step -> MSB in the paper's register picture).
# ---------------------------------------------------------------------------

def po2_weights(depth: int, tau: float, *, compensate: bool = True) -> jax.Array:
    """The constant po2 vector [2^(-k/τ')] the bitplane is 'read' against.

    With compensation this equals [e^(-k/τ)] — the exact STDP kernel on the
    integer delay grid.  On hardware this vector is free (it is the binary
    place value); here it is a constant folded into the dot product.
    """
    tau_eff = tau * LN2 if compensate else tau
    k = jnp.arange(depth, dtype=jnp.float32)
    return jnp.exp2(-k / tau_eff)


def nn_delta_from_history(history: jax.Array, amplitude: float, tau: float,
                          *, compensate: bool = True) -> jax.Array:
    """Nearest-neighbour pairing: Δw from the MSB (leading one) of history.

    ``history``: (..., depth) {0,1}.  Returns A·2^(-k*/τ') where k* is the
    index of the most recent spike, or 0 if the register is empty — the
    priority-encoder datapath of paper Fig. 10(b)/Fig. 11.
    """
    history = jnp.asarray(history)
    depth = history.shape[-1]
    any_spike = jnp.any(history != 0, axis=-1)
    k_star = jnp.argmax(history != 0, axis=-1)  # first (most recent) spike
    w = po2_weights(depth, tau, compensate=compensate)
    return jnp.where(any_spike, amplitude * w[k_star], 0.0)


def a2a_delta_from_history(history: jax.Array, amplitude: float, tau: float,
                           *, compensate: bool = True) -> jax.Array:
    """All-to-all pairing: Δw = A · (history read as a fixed-point fraction).

    Paper Fig. 2/3: the accumulation of eq. (2) is inherent in the binary
    fraction representation.  Implemented as a dot with the po2 vector —
    on TPU this is an MXU-friendly (…, depth) × (depth,) contraction.
    """
    history = jnp.asarray(history, jnp.float32)
    depth = history.shape[-1]
    w = po2_weights(depth, tau, compensate=compensate)
    return amplitude * history @ w


def magnitudes_depth_major(planes: jax.Array, amplitude: float, tau: float,
                           *, pairing: str = "nearest",
                           compensate: bool = True) -> jax.Array:
    """Per-neuron Δw magnitude from (depth, N) registers (k=0 row newest).

    The depth-major layout keeps the readout a (depth,)·(depth, N)
    contraction with no relayout — the hot path of the learning engine
    (nearest: MSB mask via a cumsum-compare along depth; all: raw bits).
    """
    bits = planes.astype(jnp.float32)
    if pairing == "nearest":
        bits = bits * (jnp.cumsum(bits, axis=0) == 1.0)
    w = po2_weights(bits.shape[0], tau, compensate=compensate)
    return amplitude * (w @ bits)


def pair_gate(pre_spike: jax.Array, post_spike: jax.Array) -> tuple[jax.Array, jax.Array]:
    """The weight-update control logic of paper §V-A.

    No update when both or neither neuron fires (XOR); when exactly one
    fires, the firing side selects LTP (post fired: pot. from pre history)
    vs LTD (pre fired: dep. from post history).  Returns (ltp_en, ltd_en)
    as {0,1} arrays broadcast over the synapse matrix.
    """
    pre = jnp.asarray(pre_spike, jnp.bool_)
    post = jnp.asarray(post_spike, jnp.bool_)
    fire_xor = jnp.logical_xor(pre, post)
    ltp_en = jnp.logical_and(fire_xor, post)   # post fired alone -> potentiate
    ltd_en = jnp.logical_and(fire_xor, pre)    # pre fired alone  -> depress
    return ltp_en, ltd_en


def synapse_update(w: jax.Array,
                   pre_spike: jax.Array, post_spike: jax.Array,
                   pre_hist: jax.Array, post_hist: jax.Array,
                   p: STDPParams,
                   *,
                   pairing: str = "nearest",
                   compensate: bool = True,
                   eta: float = 1.0,
                   w_min: float = 0.0,
                   w_max: float = 1.0) -> jax.Array:
    """One ITP-STDP step on a dense synapse matrix ``w`` (pre × post).

    ``pre_spike``: (n_pre,), ``post_spike``: (n_post,) current-step spikes.
    ``pre_hist``: (n_pre, depth), ``post_hist``: (n_post, depth) bitplanes
    (k=0 most recent).  This is the reference (pure-jnp) datapath mirrored
    by the Pallas kernel in ``repro.kernels.itp_stdp``.
    """
    if pairing == "nearest":
        ltp_mag = nn_delta_from_history(pre_hist, p.a_plus, p.tau_plus,
                                        compensate=compensate)      # (n_pre,)
        ltd_mag = nn_delta_from_history(post_hist, p.a_minus, p.tau_minus,
                                        compensate=compensate)      # (n_post,)
    elif pairing == "all":
        ltp_mag = a2a_delta_from_history(pre_hist, p.a_plus, p.tau_plus,
                                         compensate=compensate)
        ltd_mag = a2a_delta_from_history(post_hist, p.a_minus, p.tau_minus,
                                         compensate=compensate)
    else:
        raise ValueError(f"pairing must be 'nearest' or 'all', got {pairing!r}")

    ltp_en, ltd_en = pair_gate(pre_spike[:, None], post_spike[None, :])
    dw = (ltp_en * ltp_mag[:, None] - ltd_en * ltd_mag[None, :])
    return jnp.clip(w + eta * dw, w_min, w_max)
