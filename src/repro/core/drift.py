"""Mean-field synaptic drift model (paper §IV-A, eqs. 21-27, Table I).

Validates the dynamics of ITP-STDP against original STDP:

    w_{t+1} = Π_[0,1]( w_t + η·g(w_t) ),      g(w) = ∫ F(x) p(x|w) dx

with the spike-timing-difference mixture density

    p(x|w) = (1-ρ(w))·Laplace(x; b) + ρ(w)·Exp(x; μ(w), a(w))
    μ(w) = m0 + m1·w,   a(w) = a0 + a1·w,   ρ(w) = αw / (1+βw)

F is the weight-update rule under test (exact eq. 17 vs ITP eq. 20).
The paper's reported numbers (reproduced by benchmarks/drift.py):
RMSE(update curves) = 9.4753 %, equilibrium shift = 24.69 %,
convergence-time error = 7.36 % for uncompensated ITP-STDP.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.stdp import STDPParams, get_rule


@dataclasses.dataclass(frozen=True)
class DriftParams:
    """Table I of the paper."""

    b: float = 5.8         # background Laplace scale
    alpha: float = 0.58    # mixing coefficient numerator
    beta: float = 4.2      # mixing coefficient denominator
    m0: float = 0.0        # base causal delay
    m1: float = 4.5        # weight-dependent causal delay
    a0: float = 0.5        # base causal scale
    a1: float = 4.0        # weight-dependent causal scale
    eta: float = 0.2       # learning rate
    stdp: STDPParams = dataclasses.field(default_factory=STDPParams)
    # integration window.  The paper's §IV-A numbers are reproduced with a
    # truncated timing window of ±10 steps for the drift integral (eq. 22)
    # and ±20 for the update-curve RMSE — see EXPERIMENTS.md for the sweep
    # that identified these conventions.
    x_lo: float = -10.0
    x_hi: float = 10.0
    n_x: int = 8001


def density(x: jax.Array, w: jax.Array, p: DriftParams) -> jax.Array:
    """p(x | w) — eqs. 23-27.  Broadcasts x against w."""
    x = jnp.asarray(x, jnp.float32)
    w = jnp.asarray(w, jnp.float32)
    rho = p.alpha * w / (1.0 + p.beta * w)
    p_bg = jnp.exp(-jnp.abs(x) / p.b) / (2.0 * p.b)
    mu = p.m0 + p.m1 * w
    a = p.a0 + p.a1 * w
    p_c = jnp.where(x >= mu, jnp.exp(-(x - mu) / a) / a, 0.0)
    return (1.0 - rho) * p_bg + rho * p_c


def drift(w: jax.Array, rule: Callable[[jax.Array], jax.Array],
          p: DriftParams) -> jax.Array:
    """g(w) = E[Δw | w] via trapezoidal quadrature on the x grid (eq. 22)."""
    x = jnp.linspace(p.x_lo, p.x_hi, p.n_x)
    f = rule(x)                                        # (n_x,)
    w = jnp.atleast_1d(jnp.asarray(w, jnp.float32))
    pw = density(x[None, :], w[:, None], p)            # (n_w, n_x)
    g = jnp.trapezoid(f[None, :] * pw, x, axis=-1)
    return g


def make_rule(name: str, p: DriftParams) -> Callable[[jax.Array], jax.Array]:
    base = get_rule(name)
    return lambda x: base(x, p.stdp)


_LN2 = float(np.log(2.0))


def _effective_taus(rule_name: str, s: STDPParams) -> tuple[float, float]:
    """Effective base-e time constants of the exponential rule family."""
    if rule_name == "exact" or rule_name == "itp":       # itp w/ comp ≡ exact
        return s.tau_plus, s.tau_minus
    if rule_name == "itp_nocomp":                         # 2^(-x/τ)=e^(-x/(τ/ln2))
        return s.tau_plus / _LN2, s.tau_minus / _LN2
    raise ValueError(f"no closed form for rule {rule_name!r}")


def drift_analytic(w: jax.Array, rule_name: str, p: DriftParams) -> jax.Array:
    """Closed-form g(w) for exponential rules on the truncated window.

    Removes the O(h) quadrature noise of :func:`drift` caused by the causal
    density's jump at μ(w); exact for ``exact``/``itp``/``itp_nocomp``.
    """
    s = p.stdp
    tp, tm = _effective_taus(rule_name, s)
    X = float(p.x_hi)
    w = jnp.atleast_1d(jnp.asarray(w, jnp.float32))
    rho = p.alpha * w / (1.0 + p.beta * w)
    mu = p.m0 + p.m1 * w
    a = p.a0 + p.a1 * w

    lam_p = 1.0 / tp + 1.0 / p.b
    lam_m = 1.0 / tm + 1.0 / p.b
    i_bg = (s.a_plus / (2 * p.b)) * (1 - np.exp(-lam_p * X)) / lam_p \
         - (s.a_minus / (2 * p.b)) * (1 - np.exp(-lam_m * X)) / lam_m

    lam_c = 1.0 / tp + 1.0 / a
    i_c = s.a_plus * jnp.exp(-mu / tp) * (1 - jnp.exp(-lam_c * jnp.maximum(X - mu, 0.0))) \
          / (a * lam_c)
    i_c = jnp.where(mu < X, i_c, 0.0)
    return (1.0 - rho) * i_bg + rho * i_c


def iterate(w0: jax.Array, rule: Callable[[jax.Array], jax.Array] | str,
            p: DriftParams, n_steps: int = 400) -> jax.Array:
    """Weight trajectory under eq. 21.  Returns (n_steps+1, n_w).

    ``rule`` may be a callable F(x) (quadrature path) or a rule name with a
    closed form ('exact' / 'itp' / 'itp_nocomp', analytic path).
    """
    w0 = jnp.atleast_1d(jnp.asarray(w0, jnp.float32))
    if isinstance(rule, str):
        g_fn = lambda w: drift_analytic(w, rule, p)
    else:
        g_fn = lambda w: drift(w, rule, p)

    def step(w, _):
        w_next = jnp.clip(w + p.eta * g_fn(w), 0.0, 1.0)
        return w_next, w_next

    _, traj = jax.lax.scan(step, w0, None, length=n_steps)
    return jnp.concatenate([w0[None], traj], axis=0)


# ---------------------------------------------------------------------------
# Paper §IV-A metrics
# ---------------------------------------------------------------------------

def update_curve_rmse(p: DriftParams, rule_a: str = "exact",
                      rule_b: str = "itp_nocomp",
                      x_lo: float = -20.0, x_hi: float = 20.0,
                      n: int = 4001) -> float:
    """RMSE between two update curves F(x) on a symmetric window.

    On ±20 this reproduces the paper's 9.4753 % for exact vs uncompensated
    ITP with Table I amplitudes; with compensation the RMSE is exactly 0.
    """
    x = jnp.linspace(x_lo, x_hi, n)
    fa = make_rule(rule_a, p)(x)
    fb = make_rule(rule_b, p)(x)
    return float(jnp.sqrt(jnp.mean((fa - fb) ** 2)))


def equilibrium(rule_name: str, p: DriftParams, n_grid: int = 8001) -> float:
    """Largest stable fixed point of g (root with + → − sign change).

    Uses the analytic drift for exponential rules (noise-free); trajectories
    that never cross report the boundary the flow pushes them to.
    """
    w = np.linspace(0.0, 1.0, n_grid)
    if rule_name in ("exact", "itp", "itp_nocomp"):
        g = np.asarray(drift_analytic(jnp.asarray(w, jnp.float32), rule_name, p))
    else:
        g = np.asarray(drift(jnp.asarray(w, jnp.float32), make_rule(rule_name, p), p))
    s = np.sign(g)
    idx = np.where((s[:-1] > 0) & (s[1:] <= 0))[0]
    if idx.size == 0:
        return 0.0 if g[-1] < 0 else 1.0
    i = idx[-1]
    x0, x1, y0, y1 = w[i], w[i + 1], g[i], g[i + 1]
    if y1 == y0:
        return float(x0)
    return float(x0 - y0 * (x1 - x0) / (y1 - y0))


def convergence_time(traj: jax.Array, w_star: float, tol: float = 0.01) -> np.ndarray:
    """First step where |w_t − w*| < tol and stays there; per trajectory."""
    t = np.asarray(jnp.abs(traj - w_star) < tol)    # (T+1, n_w)
    T = t.shape[0]
    # last index where NOT converged, +1
    not_conv = ~t
    times = np.full(t.shape[1], T, np.int64)
    for j in range(t.shape[1]):
        nz = np.where(not_conv[:, j])[0]
        times[j] = (nz[-1] + 1) if nz.size else 0
    return times


def paper_metrics(p: DriftParams | None = None, n_steps: int = 2000,
                  w0s: np.ndarray | None = None) -> dict:
    """The three §IV-A numbers: curve RMSE, equilibrium shift, conv-time err.

    Protocol (identified by sweep, see EXPERIMENTS.md): curve RMSE on ±20;
    drift window ±10; trajectories start in [0.1, 0.6] (above the unstable
    fixed point ≈0.08, below both stable points), tol=0.01, 2000 steps.
    Reproduces paper: 9.4753 % / 24.69 % / 7.36 % → ours: 9.4753 % /
    23.8 % / 7.9 %.
    """
    p = p or DriftParams()
    w0s = w0s if w0s is not None else np.linspace(0.1, 0.6, 10)

    rmse = update_curve_rmse(p)
    eq_exact = equilibrium("exact", p)
    eq_itp = equilibrium("itp_nocomp", p)
    eq_err = abs(eq_itp - eq_exact) / max(abs(eq_exact), 1e-9)

    traj_e = iterate(jnp.asarray(w0s, jnp.float32), "exact", p, n_steps)
    traj_i = iterate(jnp.asarray(w0s, jnp.float32), "itp_nocomp", p, n_steps)
    t_e = convergence_time(traj_e, eq_exact)
    t_i = convergence_time(traj_i, eq_itp)
    conv_err = float(np.mean(np.abs(t_i - t_e) / np.maximum(t_e, 1)))

    # compensated ITP must match exactly
    rmse_comp = update_curve_rmse(p, "exact", "itp")
    return {
        "update_curve_rmse": float(rmse),
        "update_curve_rmse_compensated": float(rmse_comp),
        "equilibrium_exact": float(eq_exact),
        "equilibrium_itp_nocomp": float(eq_itp),
        "equilibrium_rel_err": float(eq_err),
        "convergence_time_rel_err": conv_err,
        "conv_time_exact_mean": float(np.mean(t_e)),
        "conv_time_itp_mean": float(np.mean(t_i)),
    }
