"""Distributed ITP-STDP learning engine (DESIGN.md §4.1).

Scales the learning engine from the paper's 4×4 prototype to layer-sized
synapse matrices across a device mesh: the weight matrix shards 2-D over
(data, model) ≙ (pre-tiles, post-tiles); each device updates its (pre ×
post) tile from *replicated* spike histories — the update is
embarrassingly parallel because the per-neuron Δw magnitudes are rank-1
(the intrinsic-timing property: no per-synapse state crosses devices).

Per step, the only communication is the postsynaptic current reduction
I_j = Σ_i s_i·w_ij — a psum over the pre-sharded axis (operand = n_post
floats), after which spikes are computed redundantly on every device of a
post-column.  Histories are O(depth · N) bits and stay replicated.

``shard_map``-manual over both axes so the collective schedule is exactly
the one the hardware analogue implies: one reduction per step, nothing
else.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import plasticity
from repro.core.engine import EngineConfig, EngineState, _quantise
from repro.core.lif import LIFState, lif_step
from repro.distributed.sharding import shard_map_compat


def shard_engine_state(state: EngineState, mesh: Mesh,
                       axes: tuple[str, str] = ("data", "model")
                       ) -> EngineState:
    """Place weights 2-D sharded, histories/neurons replicated."""
    w_sh = NamedSharding(mesh, P(*axes))
    rep = NamedSharding(mesh, P())
    return EngineState(
        w=jax.device_put(state.w, w_sh),
        pre_hist=jax.tree_util.tree_map(
            lambda a: jax.device_put(a, rep), state.pre_hist),
        post_hist=jax.tree_util.tree_map(
            lambda a: jax.device_put(a, rep), state.post_hist),
        neurons=jax.tree_util.tree_map(
            lambda a: jax.device_put(a, rep), state.neurons),
    )


def make_sharded_engine_step(cfg: EngineConfig, mesh: Mesh,
                             axes: tuple[str, str] = ("data", "model")):
    """Jitted one-step update with the weight matrix sharded over ``axes``.

    Returns ``step(state, pre_spikes) → (state', post_spikes)``; both
    histories and neuron state replicate, ``state.w`` shards (pre, post).
    """
    pre_ax, post_ax = axes
    rule = cfg.learning_rule()
    # one UpdatePlan owns backend resolution, packed-readout selection and
    # the per-tile fused / event-driven / reference update variants — the
    # same dispatch layer the dense engine and the SNN layers ride
    # (repro.plasticity.apply); this module keeps only what is genuinely
    # about sharding: partition specs, the psum, and the replicated views.
    plan = plasticity.make_plan(cfg)
    # fused and sparse datapaths default to the per-neuron word storage
    # format: the readout crossing shard_map is one uint8 word per neuron
    # ((n,), sharded along axis 0) — the packed register word for the
    # history rules (4·depth× less replicated history traffic than
    # (depth, n) float32; depth > 8 exceeds the word width and keeps the
    # unpacked operands, see EngineConfig.use_packed_history) and the
    # saturating last-spike counter for the counter rules (their only
    # kernel layout).  Row readouts ((rows, n), e.g. generic rank-1
    # rules or the reference backend) shard along their neuron axis.
    words = plan.readout_ndim() == 1

    def local_step(w, pre_spikes, pre_read, post_read, v, pre_ev):
        # w: local (pre_tile, post_tile); spikes and per-neuron readout
        # views shard along their own axes (pre over pre_ax, post over
        # post_ax).  The readout rows are rule-specific — depth bitplane
        # rows for the history rules, one counter row for the Δt rules —
        # but always per-neuron, so the tile update stays local.
        i_local = pre_spikes.astype(jnp.float32) @ w       # (post_tile,)
        i_in = jax.lax.psum(i_local, pre_ax)               # the ONE collective
        neurons, post_spikes = lif_step(LIFState(v=v), i_in, cfg.lif)
        w = plan.tile_update(w, pre_spikes, post_spikes, pre_read,
                             post_read, pre_events=pre_ev, pre_axis=pre_ax)
        if cfg.quantise:
            w = _quantise(w, cfg)
        return w, post_spikes, neurons.v

    # word readouts are (n,) uint8 sharded along axis 0; row readouts are
    # (rows, n) with the neuron axis second
    pre_read_spec = P(pre_ax) if words else P(None, pre_ax)
    post_read_spec = P(post_ax) if words else P(None, post_ax)
    sharded = shard_map_compat(
        local_step, mesh=mesh,
        in_specs=(P(pre_ax, post_ax),      # w tile
                  P(pre_ax),               # pre spikes (sharded like rows)
                  pre_read_spec,           # pre history readout
                  post_read_spec,          # post history readout
                  P(post_ax),              # membrane (sharded like cols)
                  P()),                    # global pre events (replicated)
        out_specs=(P(pre_ax, post_ax), P(post_ax), P(post_ax)))

    @jax.jit
    def step(state: EngineState, pre_spikes: jax.Array):
        pre_read = plan.state_readout(state.pre_hist)
        post_read = plan.state_readout(state.post_hist)
        # sparse: the global presynaptic event list is extracted ONCE
        # outside shard_map (pre spikes are replicated inputs) and
        # crosses as a replicated static-shape (cap,) index vector; each
        # tile translates the global indices into its own row range
        # (plan.tile_update).  Postsynaptic events are extracted locally
        # per tile — post spikes are computed redundantly on every device
        # of a post-column anyway, so the local extraction adds no
        # communication.  Dense backends cross a zero-length vector.
        pre_ev = plan.pre_events_crossing(pre_spikes)
        w, post_spikes, v = sharded(state.w,
                                    pre_spikes.astype(jnp.float32),
                                    pre_read,
                                    post_read,
                                    state.neurons.v,
                                    pre_ev)
        post_bool = post_spikes.astype(jnp.bool_)
        new_state = EngineState(
            w=w,
            pre_hist=rule.step(state.pre_hist, pre_spikes, depth=cfg.depth),
            post_hist=rule.step(state.post_hist, post_bool, depth=cfg.depth),
            neurons=type(state.neurons)(v=v),
        )
        return new_state, post_bool

    return step
