"""Spike encoding + ISI analysis (paper §IV-B, eqs. 28-30, Fig. 6).

Min-max normalisation + Bernoulli rate coding, and the inter-spike-interval
statistics used to select the spike-history depth (the paper picks depth 7,
covering 99.53 % of ISIs over three datasets).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


def minmax_normalise(x: jax.Array, axis=None, eps: float = 1e-12) -> jax.Array:
    """Per-sample min-max normalisation (eq. 28)."""
    x = jnp.asarray(x, jnp.float32)
    lo = jnp.min(x, axis=axis, keepdims=True)
    hi = jnp.max(x, axis=axis, keepdims=True)
    return (x - lo) / jnp.maximum(hi - lo, eps)


def rate_code(key: jax.Array, x_norm: jax.Array, t_steps: int) -> jax.Array:
    """Bernoulli rate coding (eqs. 29-30): returns {0,1} (t_steps, *x.shape).

    P(spike at t) = x_norm elementwise; E[rate] = x_norm.
    """
    u = jax.random.uniform(key, (t_steps, *x_norm.shape))
    return (u < x_norm[None]).astype(jnp.uint8)


class ISIStats(NamedTuple):
    counts: np.ndarray    # histogram of ISI lengths, index i = ISI of i steps
    cdf: np.ndarray       # cumulative distribution
    n_spikes: int
    n_intervals: int

    def coverage(self, depth: int) -> float:
        """Fraction of ISIs ≤ depth (paper: depth 7 → 0.9953)."""
        if depth < 1:
            return 0.0
        return float(self.cdf[min(depth, len(self.cdf) - 1)])


def isi_histogram(spikes: jax.Array, max_isi: int = 64) -> ISIStats:
    """ISI distribution of a (T, N) spike raster.

    An ISI of k means: neuron spiked at t and next at t+k.  Computed
    vectorised: for each neuron, diffs of spike-time indices.
    """
    s = np.asarray(spikes).astype(bool)          # (T, N)
    T, N = s.shape
    counts = np.zeros(max_isi + 1, np.int64)
    # vectorised per-neuron ISI: positions of spikes along T
    t_idx = np.arange(T)
    n_spikes = int(s.sum())
    n_intervals = 0
    for col in range(N):  # N is small in analysis batches; T can be long
        times = t_idx[s[:, col]]
        if times.size >= 2:
            isi = np.diff(times)
            isi = np.clip(isi, 0, max_isi)
            counts += np.bincount(isi, minlength=max_isi + 1)
            n_intervals += isi.size
    cdf = np.cumsum(counts) / max(1, counts.sum())
    return ISIStats(counts=counts, cdf=cdf, n_spikes=n_spikes,
                    n_intervals=n_intervals)


def isi_histogram_batched(spikes: jax.Array, max_isi: int = 64) -> ISIStats:
    """Fully vectorised ISI histogram for large (T, N) rasters.

    Uses the gap-run formulation: an ISI of k corresponds to a spike at t, a
    spike at t+k and no spikes in between.  We compute, for every spike, the
    distance to the previous spike via a cumulative spike-time carry.
    """
    s = np.asarray(spikes).astype(bool)
    T, N = s.shape
    t_idx = np.arange(T)[:, None]
    # last spike time at or before t (exclusive scan), -1 if none
    spike_t = np.where(s, t_idx, -1)
    prev = np.maximum.accumulate(spike_t, axis=0)
    # shift down one step: previous spike strictly before t
    prev_before = np.vstack([np.full((1, N), -1, prev.dtype), prev[:-1]])
    isi = np.where(s & (prev_before >= 0), t_idx - prev_before, 0)
    vals = isi[isi > 0]
    vals = np.clip(vals, 0, max_isi)
    counts = np.bincount(vals, minlength=max_isi + 1).astype(np.int64)
    counts[0] = 0
    cdf = np.cumsum(counts) / max(1, counts.sum())
    return ISIStats(counts=counts, cdf=cdf, n_spikes=int(s.sum()),
                    n_intervals=int(counts.sum()))


def select_history_depth(stats: ISIStats, target_coverage: float = 0.99) -> int:
    """Smallest depth whose ISI coverage meets the target (paper: 7)."""
    for d in range(1, len(stats.cdf)):
        if stats.cdf[d] >= target_coverage:
            return d
    return len(stats.cdf) - 1
