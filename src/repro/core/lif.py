"""Neuron models: LIF (paper eqs. 4-5) and Izhikevich (§IV-C DCSNN).

The LIF neuron has two datapaths, mirroring the hardware design (§V-B):

* ``lif_step``        — exact float path:  V' = α·(V−E) + E + I,  α = e^(−1/τ)
* ``lif_step_llsmu``  — fixed-point path where the α·(V−E) multiply goes
  through the LLSMu approximate multiplier, as in the paper's learning
  engine (Fig. 9).  V is kept in Q(``frac_bits``) integers.

Both return ``(state, spikes)`` and are scan-friendly.
"""
from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.llsmu import llsmu_signed


@dataclasses.dataclass(frozen=True)
class LIFParams:
    tau: float = 2.0          # membrane time constant (steps)
    v_th: float = 1.0         # firing threshold
    e_rest: float = 0.0       # resting potential

    @property
    def alpha(self) -> float:
        return math.exp(-1.0 / self.tau)


class LIFState(NamedTuple):
    v: jax.Array


def lif_init(shape, p: LIFParams) -> LIFState:
    return LIFState(v=jnp.full(shape, p.e_rest, jnp.float32))


def lif_step(state: LIFState, i_in: jax.Array, p: LIFParams,
             v_th_offset: jax.Array | float = 0.0) -> tuple[LIFState, jax.Array]:
    """Exact LIF update (eq. 4) + threshold/reset (eq. 5).

    ``v_th_offset`` raises the firing threshold per neuron (broadcast
    against ``v``) — the adaptive-threshold homeostasis term θ of the
    unsupervised training pipeline; 0 keeps the plain fixed threshold.
    """
    v = p.alpha * (state.v - p.e_rest) + p.e_rest + i_in
    spikes = (v > p.v_th + v_th_offset)
    v = jnp.where(spikes, p.e_rest, v)
    return LIFState(v=v), spikes


class LIFFixedState(NamedTuple):
    v_q: jax.Array  # int32, Q(frac_bits)


def lif_fixed_init(shape, p: LIFParams, frac_bits: int = 8) -> LIFFixedState:
    e_q = int(round(p.e_rest * (1 << frac_bits)))
    return LIFFixedState(v_q=jnp.full(shape, e_q, jnp.int32))


def lif_step_llsmu(state: LIFFixedState, i_in: jax.Array, p: LIFParams,
                   *, frac_bits: int = 8) -> tuple[LIFFixedState, jax.Array]:
    """Hardware-faithful LIF step: the leak multiply uses LLSMu (Fig. 9).

    V is Q(frac_bits) int32; α is quantised to the same format; the product
    α·(V−E) is a Q×Q→Q2 LLSMu multiply followed by a truncating shift, which
    is exactly the fixed-point datapath of the learning engine.
    ``i_in`` is float current, quantised on entry.
    """
    one = 1 << frac_bits
    alpha_q = jnp.int32(round(p.alpha * one))
    e_q = jnp.int32(round(p.e_rest * one))
    vth_q = jnp.int32(round(p.v_th * one))
    i_q = jnp.round(jnp.asarray(i_in, jnp.float32) * one).astype(jnp.int32)

    leak = llsmu_signed(state.v_q - e_q, alpha_q) >> frac_bits
    v_q = leak + e_q + i_q
    spikes = v_q > vth_q
    v_q = jnp.where(spikes, e_q, v_q)
    return LIFFixedState(v_q=v_q), spikes


# ---------------------------------------------------------------------------
# Izhikevich neuron (used by the 6-layer DCSNN in §IV-C)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class IzhikevichParams:
    a: float = 0.02
    b: float = 0.2
    c: float = -65.0
    d: float = 8.0
    v_th: float = 30.0
    dt: float = 1.0


class IzhikevichState(NamedTuple):
    v: jax.Array
    u: jax.Array


def izhikevich_init(shape, p: IzhikevichParams) -> IzhikevichState:
    v = jnp.full(shape, p.c, jnp.float32)
    return IzhikevichState(v=v, u=p.b * v)


def izhikevich_step(state: IzhikevichState, i_in: jax.Array,
                    p: IzhikevichParams,
                    v_th_offset: jax.Array | float = 0.0
                    ) -> tuple[IzhikevichState, jax.Array]:
    """One Euler step; ``v_th_offset`` is the per-neuron adaptive-threshold
    homeostasis term (broadcast against ``v``), 0 = plain threshold."""
    v, u = state.v, state.u
    dv = 0.04 * v * v + 5.0 * v + 140.0 - u + i_in
    du = p.a * (p.b * v - u)
    v = v + p.dt * dv
    u = u + p.dt * du
    spikes = v >= p.v_th + v_th_offset
    v = jnp.where(spikes, p.c, v)
    u = jnp.where(spikes, u + p.d, u)
    # clamp against Euler blow-up at large dt (standard practice); the
    # ceiling tracks the effective (homeostasis-raised) threshold
    v = jnp.clip(v, -120.0, p.v_th + v_th_offset)
    return IzhikevichState(v=v, u=u), spikes
