"""LLSMu — Logarithmic Linear Segmented Multiply (paper §II-D, eqs. 6-14).

Karatsuba decomposition of a 2N×2N-bit multiply into three N(+1)-bit
multiplies, each evaluated with the Mitchell logarithmic approximation with
the minimally-biased error-compensation constant c = 0.08333 [32].

Two datapaths are provided:

* :func:`mitchell_fixed` / :func:`llsmu_fixed` — **integer fixed-point**, a
  faithful model of the hardware datapath (Q-format mantissas, truncating
  shifts).  This is the oracle for the Pallas kernel.
* :func:`mitchell_float` — float shadow used for error analysis only.

Note on eq. (7): as typeset, the δ≥1 branch lacks the ×2 radix correction
(the true product lies in [2·2^(kx+ky), 4·2^(kx+ky)) there).  We implement
the standard minimally-biased form  2^(kx+ky+1)·(δ + c/2), which is
continuous with the δ<1 branch at δ=1 (both give 2^(kx+ky)(2+c)) and matches
[32]; DESIGN.md records this as a presumed typo.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

C_COMP = 0.08333  # error-compensation constant (paper §II-D)


def floor_log2(x: jax.Array, max_bits: int = 18) -> jax.Array:
    """Exact ⌊log2 x⌋ for non-negative int32 x (0 maps to 0).

    Implemented as a threshold count so it is exact (no float rounding) and
    vectorises on the VPU: k = #{i : x >= 2^i} - 1.
    """
    x = jnp.asarray(x, jnp.int32)
    thresholds = (1 << jnp.arange(max_bits, dtype=jnp.int32))
    k = jnp.sum(x[..., None] >= thresholds, axis=-1) - 1
    return jnp.maximum(k, 0).astype(jnp.int32)


def _var_shift(mant: jax.Array, s: jax.Array) -> jax.Array:
    """mant · 2^s with truncation for negative s (hardware barrel shift)."""
    left = jnp.maximum(s, 0)
    right = jnp.maximum(-s, 0)
    return (mant << left) >> right


def mitchell_fixed(x: jax.Array, y: jax.Array, *, frac_bits: int = 12,
                   c: float = C_COMP) -> jax.Array:
    """Mitchell approximate multiply, integer Q(frac_bits) datapath (eq. 7-9).

    Operands: non-negative int32 (intended ≤ ~9 bits so all intermediates fit
    int32).  Returns the approximate product as int32.
    """
    x = jnp.asarray(x, jnp.int32)
    y = jnp.asarray(y, jnp.int32)
    one = jnp.int32(1 << frac_bits)
    cq = jnp.int32(round(c * (1 << frac_bits)))

    kx = floor_log2(x)
    ky = floor_log2(y)
    # mantissas x/2^kx, y/2^ky in Q(frac_bits) — truncating, as in hardware
    fx = _var_shift(x, frac_bits - kx)
    fy = _var_shift(y, frac_bits - ky)
    delta = fx + fy - 2 * one                       # δ in Q(frac_bits)

    mant_lt = one + delta + cq                      # (1 + δ + c)
    mant_ge = 2 * (delta + cq // 2)                 # 2·(δ + c/2)
    mant = jnp.where(delta < one, mant_lt, mant_ge)

    p = _var_shift(mant, kx + ky - frac_bits)
    return jnp.where((x == 0) | (y == 0), 0, p).astype(jnp.int32)


def mitchell_float(x: jax.Array, y: jax.Array, *, c: float = C_COMP) -> jax.Array:
    """Float shadow of :func:`mitchell_fixed` (no quantisation error)."""
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    kx = jnp.floor(jnp.log2(jnp.maximum(x, 1.0)))
    ky = jnp.floor(jnp.log2(jnp.maximum(y, 1.0)))
    fx = x / jnp.exp2(kx) - 1.0
    fy = y / jnp.exp2(ky) - 1.0
    delta = fx + fy
    mant = jnp.where(delta < 1.0, 1.0 + delta + c, 2.0 * (delta + c / 2.0))
    p = jnp.exp2(kx + ky) * mant
    return jnp.where((x == 0) | (y == 0), 0.0, p)


def llsmu_fixed(a: jax.Array, b: jax.Array, *, n_bits: int = 4,
                frac_bits: int = 12, c: float = C_COMP) -> jax.Array:
    """LLSMu approximate multiply of two 2N-bit operands (eqs. 6, 10-14).

    Default N=4 → 8-bit × 8-bit, the paper's datapath width (Table V:
    neuron/weight bitwidth 8).  All three partial products go through
    :func:`mitchell_fixed`; recombination (eq. 13) is exact integer adds and
    shifts.  Operands larger than 2N bits are legal (Karatsuba only needs
    L < 2^N); the int32 recombination is exact while the true product stays
    below 2^31 — use n_bits=4 for ≤ ~12-bit operands.
    """
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    mask = jnp.int32((1 << n_bits) - 1)
    ha, la = a >> n_bits, a & mask
    hb, lb = b >> n_bits, b & mask

    m0 = mitchell_fixed(la, lb, frac_bits=frac_bits, c=c)
    m1 = mitchell_fixed(ha, hb, frac_bits=frac_bits, c=c)
    m2 = mitchell_fixed(ha + la, hb + lb, frac_bits=frac_bits, c=c)
    s3 = m2 - m0 - m1                                # eq. 12
    return (m1 << (2 * n_bits)) + (s3 << n_bits) + m0  # eq. 13


def llsmu_signed(a: jax.Array, b: jax.Array, **kw) -> jax.Array:
    """Sign-magnitude wrapper (the neuron datapath multiplies signed V-E)."""
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    sign = jnp.sign(a) * jnp.sign(b)
    return sign * llsmu_fixed(jnp.abs(a), jnp.abs(b), **kw)


def relative_error(a: jax.Array, b: jax.Array, **kw) -> jax.Array:
    """|LLSMu(a,b) − a·b| / max(1, a·b) — used by tests and benchmarks."""
    exact = jnp.asarray(a, jnp.int64) if False else jnp.asarray(a, jnp.float32) * jnp.asarray(b, jnp.float32)
    approx = llsmu_fixed(a, b, **kw).astype(jnp.float32)
    return jnp.abs(approx - exact) / jnp.maximum(jnp.abs(exact), 1.0)
