"""ITP-STDP learning engine (paper §III-B, §V, Figs. 4 & 9).

Couples LIF neurons, per-rule timing state (bitplane spike histories for
the intrinsic-timing rules, last-spike counters for the conventional Δt
baselines), a crossbar connectivity table and a register weight array into
a single scan-able step — the JAX equivalent of the prototype engine
(4 presynaptic × 4 postsynaptic, fully connected) and its scaled-up
versions.

Dataflow per step (matches Fig. 9 left-to-right):
  1. presyn spikes (external input or previous layer) gate the weight rows;
     each postsynaptic neuron accumulates  I_j = Σ_i s_i · w_ij   (§V-B)
  2. LIF neurons integrate I and fire
  3. the timing state is read → Δw per the selected ``LearningRule``
     (``EngineConfig.rule``), weights updated in place — unless the
     static ``learn=False`` flag freezes plasticity (the weight update
     is omitted from the trace entirely; used by the serving layer's
     eval traffic and by evaluation passes)
  4. new spikes are recorded into the state (the 'shift-in')

The engine is pure function + NamedTuple state, so it jits, vmaps over
batch, and shards over (pre, post) tiles with pjit.  The Pallas kernel in
``repro.kernels.itp_stdp`` implements step 3's fused datapath for the
kernel-backed (history) rules; see ``repro.plasticity`` for the registry
and the rule × backend matrix in ROADMAP.md.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro import plasticity
from repro.core.lif import LIFParams, LIFState, lif_init, lif_step
from repro.core.stdp import STDPParams
from repro.kernels.dispatch import resolve_packed


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    n_pre: int = 4
    n_post: int = 4
    depth: int = 7                       # spike-history depth (§IV-B)
    pairing: str = "nearest"             # engine hardware uses NN (§II-B)
    compensate: bool = True
    eta: float = 1.0 / 16.0              # po2 learning rate (shift by 4)
    w_min: float = 0.0
    w_max: float = 1.0
    w_bits: int = 8                      # weight word width incl. sign
    quantise: bool = False               # round weights to the 8-bit grid
    rule: str = "itp"                    # plasticity.rule_names()
    backend: str = "reference"           # reference | fused | fused_interpret
                                         # | sparse (event-driven)
    max_events: int | None = None        # sparse backend: static event-list
                                         # cap per side (None = population
                                         # size; excess events beyond the
                                         # cap are deterministically the
                                         # highest-indexed and are dropped)
    packed_history: bool = True          # fused* datapaths read packed uint8
                                         # register words (the paper's 8-bit
                                         # register file); False keeps the
                                         # unpacked bitplane kernel operands
                                         # (the oracle datapath).  depth > 8
                                         # exceeds the word width and falls
                                         # back to the unpacked operands
                                         # (see use_packed_history())
    stdp: STDPParams = dataclasses.field(default_factory=STDPParams)
    lif: LIFParams = dataclasses.field(default_factory=LIFParams)

    def __post_init__(self):
        # config-construction-time validation of the rule × backend cell —
        # the single shared validator (plasticity.validate_update_config)
        # keeps messages and valid-option listings identical to SNNConfig's
        plasticity.validate_update_config(
            rule=self.rule, backend=self.backend, pairing=self.pairing,
            max_events=self.max_events)

    def learning_rule(self) -> plasticity.LearningRule:
        return plasticity.get_rule(self.rule)

    def effective_compensate(self) -> bool:
        """The rule's compensation override, or this config's flag."""
        rc = self.learning_rule().compensate
        return self.compensate if rc is None else rc

    def use_packed_history(self) -> bool:
        """Whether the fused datapaths read packed uint8 register words.

        The packed word is the paper's 8-bit register file, so it only
        holds ``depth <= 8``; deeper histories (valid on the unpacked
        bitplane kernel) silently keep the unpacked operands rather than
        failing mid-trace — the two datapaths are bit-identical, packing
        is purely a bandwidth optimisation.  Resolution is owned by
        ``repro.kernels.dispatch.resolve_packed``.
        """
        return resolve_packed(self.packed_history, depth=self.depth)


class EngineState(NamedTuple):
    w: jax.Array                 # float32[n_pre, n_post]
    pre_hist: Any                # rule timing state (histories / counters)
    post_hist: Any
    neurons: LIFState            # n_post membrane


def init_engine(key: jax.Array, cfg: EngineConfig,
                w_init: jax.Array | None = None) -> EngineState:
    if w_init is None:
        w_init = jax.random.uniform(key, (cfg.n_pre, cfg.n_post),
                                    minval=0.2, maxval=0.8)
    rule = cfg.learning_rule()
    return EngineState(
        w=jnp.asarray(w_init, jnp.float32),
        pre_hist=rule.init_state(cfg.n_pre, cfg.depth),
        post_hist=rule.init_state(cfg.n_post, cfg.depth),
        neurons=lif_init((cfg.n_post,), cfg.lif),
    )


def _quantise(w: jax.Array, cfg: EngineConfig) -> jax.Array:
    """Snap to the (w_bits-1)-bit magnitude grid on [w_min, w_max]."""
    levels = (1 << (cfg.w_bits - 1)) - 1
    scale = (cfg.w_max - cfg.w_min) / levels
    return cfg.w_min + jnp.round((w - cfg.w_min) / scale) * scale


def engine_step(state: EngineState, pre_spikes: jax.Array,
                cfg: EngineConfig, *, learn: bool = True,
                v_th_offset: jax.Array | float = 0.0
                ) -> tuple[EngineState, jax.Array]:
    """One full engine cycle; returns (state', post_spikes).

    ``learn`` is a Python-static switch: ``False`` freezes plasticity —
    step 3 (the weight update) is omitted from the trace entirely, so
    dynamics run read-only on the current weights (the serving layer's
    eval-traffic mode).  ``v_th_offset`` forwards to ``lif_step`` as the
    per-neuron adaptive-threshold term θ (serving homeostasis); 0 keeps
    the plain fixed threshold.
    """
    pre_spikes = jnp.asarray(pre_spikes)

    # 1. synaptic accumulation, gated by presynaptic activity (§V-B)
    i_in = pre_spikes.astype(jnp.float32) @ state.w          # (n_post,)

    # 2. LIF integrate-and-fire
    neurons, post_spikes = lif_step(state.neurons, i_in, cfg.lif,
                                    v_th_offset=v_th_offset)

    # 3. Weight update read from the *stored* timing state (past spikes),
    #    dispatched through the plasticity apply layer: one UpdatePlan
    #    owns backend resolution (reference | fused | fused_interpret |
    #    sparse), packed-readout selection, and the fused / event-driven /
    #    reference datapath variants — see repro.plasticity.apply.  For
    #    the intrinsic-timing rules the per-neuron magnitudes are a
    #    (depth,)·(depth, N) register read with no relayout and the
    #    synapse matrix sees only a rank-1 gated outer product — O(N)
    #    readout + O(N²) add/mul, no per-pair transcendental (the paper's
    #    claim, §III); the counter rules keep their deliberately per-pair
    #    Δt datapath.
    rule = cfg.learning_rule()
    w = state.w
    if learn:
        w = plasticity.apply_update(cfg, w, pre_spikes, post_spikes,
                                    state.pre_hist, state.post_hist)
        if cfg.quantise:
            w = _quantise(w, cfg)

    # 4. record the new spikes (history shift-in / counter reset)
    pre_hist = rule.step(state.pre_hist, pre_spikes, depth=cfg.depth)
    post_hist = rule.step(state.post_hist, post_spikes, depth=cfg.depth)
    return EngineState(w, pre_hist, post_hist, neurons), post_spikes


def run_engine(state: EngineState, spike_train: jax.Array,
               cfg: EngineConfig, *, learn: bool = True
               ) -> tuple[EngineState, jax.Array]:
    """Scan the engine over a (T, n_pre) input raster; returns post raster."""
    def step(s, x):
        s, out = engine_step(s, x, cfg, learn=learn)
        return s, out

    state, post = jax.lax.scan(step, state, spike_train)
    return state, post


def prototype_engine(key: jax.Array) -> tuple[EngineConfig, EngineState]:
    """The paper's 4×4 fully connected prototype (§III-B / Table V row 1)."""
    cfg = EngineConfig(n_pre=4, n_post=4)
    return cfg, init_engine(key, cfg)


# ---------------------------------------------------------------------------
# Batched population path: a fleet of independent engine replicas.
#
# One engine is the paper's unit of hardware; at serving/benchmark scale we
# run R replicas (per-user networks, ensemble members, hyperparameter
# sweeps) as a single vmapped program so XLA fuses the whole population
# into one device launch per step.  All replica state leaves carry a
# leading (R,) axis; the same EngineConfig (including ``backend``) applies
# to every replica.
# ---------------------------------------------------------------------------

def init_engine_population(key: jax.Array, cfg: EngineConfig,
                           n_replicas: int) -> EngineState:
    """Independent per-replica init: R engines from R split keys."""
    keys = jax.random.split(key, n_replicas)
    return jax.vmap(lambda k: init_engine(k, cfg))(keys)


def run_engine_population(states: EngineState, spike_trains: jax.Array,
                          cfg: EngineConfig, *, learn: bool = True
                          ) -> tuple[EngineState, jax.Array]:
    """Scan every replica over its own raster; ``spike_trains``: (R, T, n_pre).

    Returns (states', post rasters (R, T, n_post)).  ``learn=False``
    freezes plasticity in every replica (see :func:`engine_step`).
    """
    return jax.vmap(lambda s, x: run_engine(s, x, cfg, learn=learn))(
        states, spike_trains)
