"""Core library: the paper's contribution as composable JAX modules."""
from repro.core.stdp import (  # noqa: F401
    STDPParams, exact_stdp, itp_stdp, linear_stdp, imstdp, get_rule,
    po2_weights, nn_delta_from_history, a2a_delta_from_history,
    pair_gate, synapse_update,
)
from repro.core.history import (  # noqa: F401
    SpikeHistory, init_history, push, as_register, pack_words, unpack_words,
)
from repro.core.lif import (  # noqa: F401
    LIFParams, LIFState, lif_init, lif_step, lif_step_llsmu,
    IzhikevichParams, izhikevich_init, izhikevich_step,
)
from repro.core.llsmu import mitchell_fixed, mitchell_float, llsmu_fixed, llsmu_signed  # noqa: F401
from repro.core.encoding import minmax_normalise, rate_code, isi_histogram_batched, select_history_depth  # noqa: F401
from repro.core.engine import EngineConfig, EngineState, init_engine, engine_step, run_engine  # noqa: F401
