"""Deprecated shim: the counter-based exact-STDP baseline engine.

The CounterEngine (conventional digital STDP, §I: per-neuron last-spike
counters, per-pair Δt + base-e exponential) is now a first-class learning
rule — ``EngineConfig(rule="exact")`` via ``repro.plasticity`` — so the
baseline shares the engine's LIF dynamics, scan loop, backends and
benchmarks instead of maintaining a parallel one-off API.  A counter
``window`` of W maps to a rule ``depth`` of W+1 (valid delays t ∈ [0, W],
saturation at W+1 — identical semantics to the old standalone engine).

These aliases keep old call sites green (pinned by
tests/test_plasticity.py) but now emit ``DeprecationWarning`` pointing at
the registry path; new code should use ``repro.core.engine`` with
``rule="exact"`` directly.
"""
from __future__ import annotations

import warnings

from repro.core.engine import (EngineConfig, EngineState, engine_step,
                               init_engine, run_engine)
from repro.core.lif import LIFParams
from repro.core.stdp import STDPParams

CounterEngineState = EngineState


def _deprecated(alias: str, target: str) -> None:
    warnings.warn(
        f"repro.core.baseline.{alias} is deprecated: the counter baseline "
        f"is the registry rule EngineConfig(rule='exact') — use "
        f"repro.core.engine.{target} directly",
        DeprecationWarning, stacklevel=3)


def CounterEngineConfig(n_pre: int = 4, n_post: int = 4, window: int = 7,
                        eta: float = 1.0 / 16.0, w_min: float = 0.0,
                        w_max: float = 1.0,
                        stdp: STDPParams | None = None,
                        lif: LIFParams | None = None) -> EngineConfig:
    """Deprecated: build the equivalent ``EngineConfig(rule="exact")``."""
    _deprecated("CounterEngineConfig", "EngineConfig(rule='exact')")
    return EngineConfig(
        n_pre=n_pre, n_post=n_post, depth=window + 1, rule="exact",
        eta=eta, w_min=w_min, w_max=w_max,
        stdp=stdp if stdp is not None else STDPParams(),
        lif=lif if lif is not None else LIFParams())


def init_counter_engine(key, cfg, w_init=None):
    """Deprecated alias for :func:`repro.core.engine.init_engine`."""
    _deprecated("init_counter_engine", "init_engine")
    _check_exact(cfg)
    return init_engine(key, cfg, w_init)


def counter_engine_step(state, pre_spikes, cfg):
    """Deprecated alias for :func:`repro.core.engine.engine_step`."""
    _deprecated("counter_engine_step", "engine_step")
    _check_exact(cfg)
    return engine_step(state, pre_spikes, cfg)


def run_counter_engine(state, spike_train, cfg):
    """Deprecated alias for :func:`repro.core.engine.run_engine`."""
    _deprecated("run_counter_engine", "run_engine")
    _check_exact(cfg)
    return run_engine(state, spike_train, cfg)


def _check_exact(cfg: EngineConfig) -> None:
    if not isinstance(cfg, EngineConfig):
        raise TypeError(
            "CounterEngineConfig now *returns* an EngineConfig(rule='exact') "
            f"— call it rather than passing {type(cfg).__name__}")
    if cfg.rule != "exact":
        raise ValueError(
            f"counter-engine aliases expect rule='exact', got {cfg.rule!r}; "
            "use repro.core.engine directly for other rules")
