"""Counter-based exact-STDP baseline engine (what the paper optimises away).

Conventional digital STDP (§I, [21]/[28]-style): every neuron carries a
*last-spike-time counter*; on a spike event the timing difference
Δt = t_post − t_pre is computed per synapse pair and the base-e exponential
is evaluated per pair.  Per step this costs O(n_pre · n_post) exponential
evaluations + subtractions, versus ITP-STDP's O(n_pre + n_post) register
reads and one rank-1 outer product — the asymmetry Tables III-V monetise
in LUTs/area/energy, reproduced here as the measured-throughput baseline
in ``benchmarks/engine_cost.py``.

Semantics: nearest-neighbour pairing over a finite window (the counter
saturates at ``window``), matching the learning engine's configuration.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.lif import LIFParams, LIFState, lif_init, lif_step
from repro.core.stdp import STDPParams, pair_gate


@dataclasses.dataclass(frozen=True)
class CounterEngineConfig:
    n_pre: int = 4
    n_post: int = 4
    window: int = 7                     # counter saturation (≙ history depth)
    eta: float = 1.0 / 16.0
    w_min: float = 0.0
    w_max: float = 1.0
    stdp: STDPParams = dataclasses.field(default_factory=STDPParams)
    lif: LIFParams = dataclasses.field(default_factory=LIFParams)


class CounterEngineState(NamedTuple):
    w: jax.Array              # (n_pre, n_post)
    t_pre: jax.Array          # int32 (n_pre,) steps since last pre spike
    t_post: jax.Array         # int32 (n_post,)
    neurons: LIFState


def init_counter_engine(key: jax.Array, cfg: CounterEngineConfig,
                        w_init: jax.Array | None = None) -> CounterEngineState:
    if w_init is None:
        w_init = jax.random.uniform(key, (cfg.n_pre, cfg.n_post),
                                    minval=0.2, maxval=0.8)
    big = jnp.int32(cfg.window + 1)
    return CounterEngineState(
        w=jnp.asarray(w_init, jnp.float32),
        t_pre=jnp.full((cfg.n_pre,), big),
        t_post=jnp.full((cfg.n_post,), big),
        neurons=lif_init((cfg.n_post,), cfg.lif),
    )


def counter_engine_step(state: CounterEngineState, pre_spikes: jax.Array,
                        cfg: CounterEngineConfig
                        ) -> tuple[CounterEngineState, jax.Array]:
    """One step of the conventional counter-based STDP engine.

    The Δw computation is deliberately per-pair: Δt is formed for every
    (pre, post) synapse and exp() evaluated per synapse — the datapath the
    paper's intrinsic-timing representation collapses to a register read.
    """
    pre = jnp.asarray(pre_spikes)
    i_in = pre.astype(jnp.float32) @ state.w
    neurons, post = lif_step(state.neurons, i_in, cfg.lif)

    p = cfg.stdp
    # per-pair timing difference from the counters (O(N²) work)
    dt_ltp = state.t_pre[:, None].astype(jnp.float32)    # pre fired dt ago
    dt_ltd = state.t_post[None, :].astype(jnp.float32)
    ltp_valid = state.t_pre[:, None] <= cfg.window
    ltd_valid = state.t_post[None, :] <= cfg.window
    ltp_mag = p.a_plus * jnp.exp(-dt_ltp / p.tau_plus) * ltp_valid
    ltd_mag = p.a_minus * jnp.exp(-dt_ltd / p.tau_minus) * ltd_valid

    ltp_en, ltd_en = pair_gate(pre[:, None], post[None, :])
    dw = ltp_en * ltp_mag - ltd_en * ltd_mag
    w = jnp.clip(state.w + cfg.eta * dw, cfg.w_min, cfg.w_max)

    big = cfg.window + 1
    t_pre = jnp.where(pre.astype(bool), 0,
                      jnp.minimum(state.t_pre + 1, big)).astype(jnp.int32)
    t_post = jnp.where(post, 0,
                       jnp.minimum(state.t_post + 1, big)).astype(jnp.int32)
    return CounterEngineState(w, t_pre, t_post, neurons), post


def run_counter_engine(state: CounterEngineState, spike_train: jax.Array,
                       cfg: CounterEngineConfig
                       ) -> tuple[CounterEngineState, jax.Array]:
    def step(s, x):
        return counter_engine_step(s, x, cfg)
    return jax.lax.scan(step, state, spike_train)
