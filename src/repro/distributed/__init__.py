from repro.distributed import compression, fault_tolerance, sharding
