"""Sharding rules: logical-axis specs → mesh PartitionSpecs for every arch.

Scheme (DESIGN.md §6):
  * batch        → ('pod', 'data') when a pod axis exists, else ('data',)
  * TP ('tp')    → 'model'  (heads / d_ff / vocab / d_inner)
  * FSDP ('fsdp')→ 'data'   (second weight dim, ZeRO-3 style)
  * experts      → 'model' when E divides the axis (EP), else TP inside
                   each expert (decided per arch by the divisibility guard)
  * sequence     → 'model' for long-context KV caches (serve-time SP)

Every rule passes a divisibility guard: an axis that does not divide the
dim is dropped (GSPMD could pad, but deliberate replication beats silent
padding + resharding churn).  ``constrain`` applies activation constraints
only when a mesh is active, so the same model code runs unsharded on CPU
tests.
"""
from __future__ import annotations

import contextlib
import re
import threading
from typing import Any, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def shard_map_compat(f, *, mesh, in_specs, out_specs, axis_names=None):
    """Version-compat ``shard_map``: the top-level ``jax.shard_map`` API
    (``check_vma``/``axis_names``) when this jax has it, else the
    ``jax.experimental.shard_map`` API (``check_rep``; partial-manual
    ``axis_names`` translates to its ``auto`` complement).  The single
    shim every shard_map call site (``repro.core.engine_sharded``, the
    multi-device subprocess tests) routes through, so the supported-API
    decision lives in exactly one place.
    """
    if hasattr(jax, "shard_map"):
        kw = {"check_vma": False}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, **kw)
    from jax.experimental.shard_map import shard_map as _sm
    kw = {"check_rep": False}
    if axis_names is not None:
        kw["auto"] = frozenset(mesh.axis_names) - frozenset(axis_names)
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)


def current_mesh() -> Mesh | None:
    return getattr(_state, "mesh", None)


def sharding_profile() -> str:
    """Parallelism profile for weights/activations.

    * 'fsdp'       — ZeRO-3: weights sharded over 'data', TP over 'model'
                     (the default; right for ≥10B models).
    * 'replicated' — DP+TP: weights replicated over 'data'; kills FSDP
                     weight gathers at the cost of HBM.
    * 'dp'         — pure data parallelism: weights fully replicated,
                     batch sharded over ('data','model') jointly.  For
                     sub-1B models the per-layer TP activation
                     all-reduces dominate the collective term (§Perf
                     cell 1); pure DP trades them for one gradient
                     all-reduce (0.6B f32 ⇒ 2.4 GB) — a ~20× predicted
                     reduction, affordable whenever params+opt fit HBM.
    * 'dp_zero3'   — pure-DP compute with weights/opt sharded over the
                     (compute-idle) 'model' axis, gathered on use: the
                     HBM-fitting variant of 'dp' (replicated state 7.2 GB
                     → 0.45 GB for qwen3-0.6b) at the cost of per-layer
                     weight all-gathers (≈ params bytes per pass).
    """
    return getattr(_state, "profile", "fsdp")


@contextlib.contextmanager
def use_sharding_profile(profile: str):
    prev = sharding_profile()
    _state.profile = profile
    try:
        yield
    finally:
        _state.profile = prev


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None):
    prev = current_mesh()
    _state.mesh = mesh
    try:
        if mesh is not None:
            with mesh:   # Mesh is a context manager (thread-resources env)
                yield mesh
        else:
            yield None
    finally:
        _state.mesh = prev


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    if sharding_profile() in ("dp", "dp_zero3"):
        # pure DP: the model axis carries batch too
        return (("pod", "data", "model") if "pod" in mesh.axis_names
                else ("data", "model"))
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def _resolve_axis(logical, mesh: Mesh):
    """logical axis name → physical mesh axis (or tuple), or None."""
    if logical is None:
        return None
    if logical == "batch":
        return batch_axes(mesh)
    profile = sharding_profile()
    if logical == "tp":
        return None if profile in ("dp", "dp_zero3") else "model"
    if logical == "fsdp":
        if profile == "fsdp":
            return "data"
        if profile == "dp_zero3":
            return "model"
        return None
    return logical


def _axis_size(ax, mesh: Mesh) -> int:
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= mesh.shape[a]
        return n
    return mesh.shape[ax]


def _manual_axes() -> frozenset:
    """Mesh axes that are Manual in the ambient abstract mesh.

    Inside a partial-manual ``shard_map`` (e.g. manual over 'pod' in the
    multi-pod train step) activation constraints must not mention the
    manual axes — the local shard has no pod dimension.
    """
    try:
        am = jax.sharding.get_abstract_mesh()
        return frozenset(
            name for name, t in zip(am.axis_names, am.axis_types)
            if "Manual" in str(t))
    except Exception:  # pragma: no cover - very old jax
        return frozenset()


def _strip_manual(ax, manual):
    if ax is None:
        return None
    if isinstance(ax, tuple):
        kept = tuple(a for a in ax if a not in manual)
        return kept if kept else None
    return None if ax in manual else ax


def logical_to_spec(spec: Sequence, shape: tuple[int, ...],
                    mesh: Mesh) -> P:
    """Right-aligned logical spec → PartitionSpec with divisibility guard.

    ``spec`` names the trailing dims; leading (layer-stack) dims replicate.
    """
    spec = tuple(spec)
    if len(spec) > len(shape):
        spec = spec[len(spec) - len(shape):]
    pad = len(shape) - len(spec)
    manual = _manual_axes()
    out = [None] * pad
    for dim, logical in zip(shape[pad:], spec):
        ax = _strip_manual(_resolve_axis(logical, mesh), manual)
        if ax is not None and dim % _axis_size(ax, mesh) != 0:
            ax = None
        out.append(ax)
    return P(*out)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

# ordered (regex on '/'-joined path, logical spec for the trailing dims)
_PARAM_RULES: list[tuple[str, tuple]] = [
    (r"embed/tok$", ("tp", "fsdp")),
    (r"embed/out$", ("fsdp", "tp")),
    (r"attn/wq$", ("fsdp", "tp")),
    (r"attn/wk$", ("fsdp", "tp")),
    (r"attn/wv$", ("fsdp", "tp")),
    (r"attn/wo$", ("tp", "fsdp")),
    (r"attn/b[qkv]$", ("tp",)),
    (r"mlp/(gate|up)$", ("fsdp", "tp")),
    (r"mlp/down$", ("tp", "fsdp")),
    (r"mlp/up_bias$", ("tp",)),
    (r"moe/router$", ("fsdp", None)),
    (r"moe/(gate|up)$", ("ep", "fsdp", "tp")),     # resolved per arch below
    (r"moe/down$", ("ep", "tp", "fsdp")),
    (r"shared/(gate|up)$", ("fsdp", "tp")),
    (r"shared/down$", ("tp", "fsdp")),
    (r"shared/route$", (None, None)),
    (r"ssm/wz$", ("fsdp", "tp")),
    (r"ssm/wxbc$", ("fsdp", "tp")),
    (r"ssm/wdt$", ("fsdp", None)),
    (r"ssm/conv_w$", (None, "tp")),
    (r"ssm/conv_b$", ("tp",)),
    (r"ssm/norm_scale$", ("tp",)),
    (r"ssm/out_proj$", ("tp", "fsdp")),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_spec_for(path_str: str, shape: tuple[int, ...], cfg,
                   mesh: Mesh) -> P:
    for pattern, spec in _PARAM_RULES:
        if re.search(pattern, path_str):
            if pattern == r"embed/tok$" and "pod" in mesh.axis_names:
                # XLA SPMD-partitioner workaround (verified crash,
                # spmd_partitioner_util.cc Check failure): a gather from a
                # table sharded on the *auto* 'data' axis inside a region
                # that is *manual* over 'pod' miscomputes its device
                # groups.  Dropping the fsdp factor on the token table
                # (keeping TP over vocab) sidesteps it; worst case
                # (qwen1.5-32b) costs 585 MB/device of replicated
                # embedding+opt state — well within HBM.
                spec = ("tp", None)
            if "ep" in spec:
                # expert-parallel when E (padded) divides the model axis,
                # else the expert dim replicates and TP shards inside
                if cfg.experts_alloc % mesh.shape["model"] == 0:
                    # EP: experts on 'model'; inner dims FSDP-only
                    spec = tuple("tp" if s == "ep" else
                                 (None if s == "tp" else s) for s in spec)
                else:
                    spec = tuple(None if s == "ep" else s for s in spec)
            return logical_to_spec(spec, shape, mesh)
    return P()  # norms, scalars, small vectors: replicate


def param_shardings(cfg, params, mesh: Mesh):
    """Pytree of NamedShardings matching ``params``."""
    def one(path, leaf):
        spec = param_spec_for(_path_str(path), leaf.shape, cfg, mesh)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, params)


def param_spec_tree(cfg, params_shape, mesh: Mesh):
    def one(path, leaf):
        return param_spec_for(_path_str(path), leaf.shape, cfg, mesh)
    return jax.tree_util.tree_map_with_path(one, params_shape)


# ---------------------------------------------------------------------------
# Activation constraints (no-ops without an active mesh)
# ---------------------------------------------------------------------------

def constrain(x: jax.Array, spec: Sequence) -> jax.Array:
    mesh = current_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, logical_to_spec(spec, x.shape, mesh))


def batch_spec(mesh: Mesh, ndim: int, *, seq_axis=None) -> P:
    """(B, ...) arrays: batch over ('pod','data'); optional seq over model."""
    out: list[Any] = [batch_axes(mesh)] + [None] * (ndim - 1)
    if seq_axis is not None:
        out[seq_axis] = "model"
    return P(*out)


# ---------------------------------------------------------------------------
# Decode-cache shardings (serving)
# ---------------------------------------------------------------------------

def _div(dim: int, ax, mesh: Mesh) -> bool:
    return ax is not None and dim % _axis_size(ax, mesh) == 0


def _batch_ax(dim: int, mesh: Mesh):
    """Largest batch sharding ('pod','data') → ('data',) → None that divides."""
    full = batch_axes(mesh)
    if _div(dim, full, mesh):
        return full
    if _div(dim, ("data",), mesh):
        return ("data",)
    return None


def kv_cache_spec(shape: tuple[int, ...], mesh: Mesh) -> P:
    """(L, B, T, K, hd) KV cache (or (L,B,T,K,1) scale) sharding.

    Preference order: KV heads on 'model' (TP-aligned with the attention
    projections); if the head count does not divide, fall back to sequence
    parallelism — shard the context axis T on 'model' (softmax reductions
    over T become GSPMD psums).  Batch goes over ('pod','data') when it
    divides, which it does for decode_32k (128) but not long_500k (1);
    there T additionally shards over 'data'.
    """
    L, B, T, K = shape[:4]
    b_ax = _batch_ax(B, mesh)
    k_ax = "model" if _div(K, "model", mesh) else None
    t_ax = None
    if k_ax is None and _div(T, ("model",), mesh):
        t_ax = ("model",)
    if b_ax is None:
        # latency-mode decode (B=1): spread the context over 'data' too
        if t_ax == ("model",) and _div(T, ("data", "model"), mesh):
            t_ax = ("data", "model")
        elif t_ax is None and _div(T, ("data",), mesh):
            t_ax = ("data",)
    rest = [None] * (len(shape) - 4)
    return P(None, b_ax, t_ax, k_ax, *rest)


def ssm_cache_specs(conv_shape: tuple[int, ...], state_shape: tuple[int, ...],
                    mesh: Mesh) -> tuple[P, P]:
    """SSM decode caches: conv (L,B,W,conv_dim), state (L,B,g,r,N,P).

    conv_dim and the head axis r align with the TP sharding of wxbc /
    the SSD head grouping, so both shard on 'model' when divisible.
    """
    Lb, B, W, conv_dim = conv_shape
    b_ax = _batch_ax(B, mesh)
    conv_spec = P(None, b_ax, None,
                  "model" if _div(conv_dim, "model", mesh) else None)
    _, Bs, g, r = state_shape[:4]
    r_ax = "model" if _div(r, "model", mesh) else None
    state_spec = P(None, _batch_ax(Bs, mesh), None, r_ax, None, None)
    return conv_spec, state_spec


def decode_cache_shardings(cache, mesh: Mesh):
    """NamedSharding pytree matching a DecodeCache (of arrays or SDS)."""
    def ns(spec):
        return NamedSharding(mesh, spec)

    def kv_shardings(kv):
        if kv is None:
            return None
        out = type(kv)(
            k=ns(kv_cache_spec(kv.k.shape, mesh)),
            v=ns(kv_cache_spec(kv.v.shape, mesh)),
            k_scale=(ns(kv_cache_spec(kv.k_scale.shape, mesh))
                     if kv.k_scale is not None else None),
            v_scale=(ns(kv_cache_spec(kv.v_scale.shape, mesh))
                     if kv.v_scale is not None else None),
        )
        return out

    def ssm_shardings(ssm):
        if ssm is None:
            return None
        conv_spec, state_spec = ssm_cache_specs(ssm.conv.shape,
                                                ssm.state.shape, mesh)
        return type(ssm)(conv=ns(conv_spec), state=ns(state_spec))

    def cross_sharding(x):
        if x is None:
            return None
        # (n_cross, B, Nv, K, hd)
        _, B, Nv, K = x.shape[:4]
        return ns(P(None, _batch_ax(B, mesh), None,
                    "model" if _div(K, "model", mesh) else None, None))

    return type(cache)(
        kv=kv_shardings(cache.kv),
        global_kv=kv_shardings(cache.global_kv),
        ssm=ssm_shardings(cache.ssm),
        cross_k=cross_sharding(cache.cross_k),
        cross_v=cross_sharding(cache.cross_v),
    )
