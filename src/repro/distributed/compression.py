"""Po2-compressed cross-pod gradient reduction (beyond-paper, DESIGN.md §4.2).

The paper's core representation — sign · 2^e — applied to the slowest link
in a multi-pod training system: the inter-pod gradient all-reduce.  Each
pod's gradient shard is encoded to the 8-bit wire format of
``repro.kernels.po2_quant`` (sign bit + 7-bit biased exponent), exchanged
with an ``all_gather`` over the ``pod`` axis (int8 on the wire → 4× fewer
bytes than f32, 2× fewer than bf16), decoded locally, and averaged.

Implementation note: the nonlinearity of the po2 codec rules out a direct
``psum`` of encoded values, so the exchange is gather-then-reduce — for the
2-pod production mesh the wire cost equals one compressed all-reduce.  The
function is a ``shard_map`` manual only over ``pod`` (``axis_names``), so
FSDP/TP sharding of the gradients over data/model axes is preserved inside
(GSPMD keeps handling those axes).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.po2_quant.ref import po2_decode_ref, po2_encode_ref


def _encode_int8(x: jax.Array) -> jax.Array:
    """f32 → int8 wire bytes (sign bit 7, biased exponent bits 0-6)."""
    return po2_encode_ref(x).astype(jnp.int8)


def _decode_int8(c: jax.Array) -> jax.Array:
    return po2_decode_ref(c.astype(jnp.int32) & 0xFF)


def _pod_mean_one(g: jax.Array, axis: str) -> jax.Array:
    wire = _encode_int8(g.astype(jnp.float32))
    gathered = jax.lax.all_gather(wire, axis)          # (n_pod, ...) int8
    return jnp.mean(_decode_int8(gathered), axis=0).astype(g.dtype)


def pod_mean_tree(grads, *, compress: bool, axis: str = "pod"):
    """Mean a gradient pytree across ``axis`` — po2-compressed or plain.

    Must be called *inside* a ``shard_map`` that is manual over ``axis``
    (see ``repro.train.train_step``): after the mean the result is
    genuinely replicated across pods, so the enclosing ``out_specs=P()``
    is truthful.
    """
    if compress:
        return jax.tree_util.tree_map(partial(_pod_mean_one, axis=axis),
                                      grads)
    return jax.tree_util.tree_map(lambda x: jax.lax.pmean(x, axis), grads)


def compression_error(grads) -> jax.Array:
    """Relative L2 error of the po2 quantiser over a gradient pytree."""
    def err(x):
        x = x.astype(jnp.float32)
        q = _decode_int8(_encode_int8(x))
        return jnp.sum((q - x) ** 2), jnp.sum(x ** 2)
    pairs = [err(x) for x in jax.tree_util.tree_leaves(grads)]
    num = sum(p[0] for p in pairs)
    den = sum(p[1] for p in pairs)
    return jnp.sqrt(num / jnp.maximum(den, 1e-30))
