"""Fault tolerance: watchdog straggler detection, failure-injected restart,
elastic resume.

``TrainingRunner`` wraps any ``(state, batch) → state`` step with the
production control loop:

  * checkpoint every ``ckpt_every`` steps (async, checksum-manifested);
  * on a step failure (node loss is injected/simulated as an exception),
    restore the latest valid checkpoint and replay — the data stream is
    keyed by step number, so replayed steps see identical batches
    (deterministic recovery);
  * a ``Watchdog`` tracks per-step wall time against a rolling median and
    flags stragglers (> ``k×`` median) — on real fleets this signal drives
    hot-spare swaps; here it is logged and unit-tested via a fake clock;
  * ``resume(mesh)`` re-shards the restored state onto a *different* mesh
    (elastic DP resize after losing a pod).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax

from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_checkpoint,
                                         restore_checkpoint)


class Watchdog:
    """Rolling-median straggler detector with an injectable clock."""

    def __init__(self, threshold: float = 3.0, window: int = 32,
                 clock: Callable[[], float] = time.monotonic):
        self.threshold = threshold
        self.window = window
        self.clock = clock
        self.durations: list[float] = []
        self.stragglers: list[tuple[int, float, float]] = []  # (step, dur, med)
        self._t0: float | None = None

    def start(self):
        self._t0 = self.clock()

    def stop(self, step: int) -> bool:
        """Record the step duration; returns True if it was a straggler."""
        dur = self.clock() - self._t0
        hist = self.durations[-self.window:]
        self.durations.append(dur)
        if len(hist) >= 8:
            med = sorted(hist)[len(hist) // 2]
            if dur > self.threshold * med:
                self.stragglers.append((step, dur, med))
                return True
        return False


@dataclasses.dataclass
class RunnerConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep: int = 3
    max_restarts: int = 5
    straggler_threshold: float = 3.0


class FailureInjector:
    """Deterministic failure schedule for tests: fail at the given steps."""

    def __init__(self, fail_at: set[int] | None = None):
        self.fail_at = set(fail_at or ())
        self.fired: set[int] = set()

    def maybe_fail(self, step: int):
        if step in self.fail_at and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


class TrainingRunner:
    """Checkpoint/restart training loop with straggler monitoring."""

    def __init__(self, cfg: RunnerConfig, step_fn: Callable,
                 batch_fn: Callable[[int], Any],
                 clock: Callable[[], float] = time.monotonic):
        """``step_fn(state, batch) → (state, metrics)``;
        ``batch_fn(step) → batch`` (step-keyed for deterministic replay)."""
        self.cfg = cfg
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt = AsyncCheckpointer(cfg.ckpt_dir, keep=cfg.keep)
        self.watchdog = Watchdog(cfg.straggler_threshold, clock=clock)
        self.restarts = 0
        self.log: list[dict] = []

    def _restore(self, state_template):
        step = latest_checkpoint(self.cfg.ckpt_dir)
        if step is None:
            return 0, state_template
        state = restore_checkpoint(self.cfg.ckpt_dir, step, state_template)
        return step, state

    def run(self, state, n_steps: int,
            injector: FailureInjector | None = None):
        """Run to ``n_steps``, surviving injected failures via restart."""
        start = 0
        template = state
        while True:
            try:
                for step in range(start, n_steps):
                    if injector is not None:
                        injector.maybe_fail(step)
                    batch = self.batch_fn(step)
                    self.watchdog.start()
                    state, metrics = self.step_fn(state, batch)
                    straggled = self.watchdog.stop(step)
                    self.log.append({"step": step, "straggler": straggled,
                                     **{k: float(v) for k, v in
                                        (metrics or {}).items()
                                        if hasattr(v, "__float__")}})
                    if (step + 1) % self.cfg.ckpt_every == 0:
                        self.ckpt.save(step + 1, state)
                self.ckpt.wait()
                self.ckpt.save(n_steps, state)
                self.ckpt.wait()
                return state
            except RuntimeError as e:
                self.restarts += 1
                if self.restarts > self.cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.cfg.max_restarts}") from e
                self.ckpt.wait()
                start, state = self._restore(template)
                self.log.append({"event": "restart", "resume_step": start,
                                 "cause": str(e)})


def elastic_reshard(state, shardings):
    """Re-place a (restored) state pytree onto a new mesh's shardings."""
    return jax.tree_util.tree_map(
        lambda x, s: jax.device_put(x, s), state, shardings)
