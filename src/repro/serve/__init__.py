from repro.serve.serving import (Request, ServeConfig, Server, init_cache,
                                 make_serve_step, prefill, sample)
