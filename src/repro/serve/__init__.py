"""Online-plasticity serving: per-user SNNs whose resident state is the
paper's packed uint8 register word (see docs/architecture.md).

:mod:`repro.serve.session` owns the per-session state and the LRU store;
:mod:`repro.serve.serving` owns the batched continual-STDP step and the
async server loop.  Entry point: ``python -m repro.launch.serve``.
"""

from repro.serve.serving import (Request, Result, ServeConfig, Server,
                                 serve_step)
from repro.serve.session import SessionState, SessionStore
