"""Serving: one-token decode steps, chunked prefill, and a batched
continuous-batching server loop.

``make_serve_step`` builds the jitted decode step that the decode_32k /
long_500k dry-run cells lower: one new token for every sequence in the
batch against a seq_len-deep KV/SSM cache.  ``Server`` is a minimal
continuous-batching engine over it (slot-based, greedy or temperature
sampling) used by the serving example.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import transformer

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    max_tokens: int                  # KV-cache depth (context length)
    batch: int
    kv_dtype: str = "bfloat16"       # bfloat16 | int8
    temperature: float = 0.0         # 0 → greedy
    unroll: bool = False             # unroll layer scans (measurement only)


def make_serve_step(cfg, serve_cfg: ServeConfig) -> Callable:
    """Returns ``step(params, cache, tokens (B,1), pos) → (logits, cache')``."""

    def step(params: Params, cache: transformer.DecodeCache,
             tokens: jax.Array, pos: jax.Array,
             vis_embed: jax.Array | None = None):
        kw = {"vis_embed": vis_embed} if vis_embed is not None else {}
        return transformer.decode_step(params, cfg, cache, pos,
                                       tokens=tokens,
                                       unroll=serve_cfg.unroll, **kw)

    return step


def init_cache(cfg, serve_cfg: ServeConfig) -> transformer.DecodeCache:
    dt = jnp.int8 if serve_cfg.kv_dtype == "int8" else jnp.bfloat16
    return transformer.init_decode_cache(cfg, serve_cfg.batch,
                                         serve_cfg.max_tokens, kv_dtype=dt)


def prefill(params: Params, cfg, cache: transformer.DecodeCache,
            tokens: jax.Array, serve_step: Callable,
            vis_embed: jax.Array | None = None
            ) -> tuple[jax.Array, transformer.DecodeCache]:
    """Sequential prefill through the decode path (small-scale serving).

    Production prefill runs the batched forward; the decode-path loop keeps
    this example-scale implementation cache-exact for every family
    (KV, ring-SWA, SSM state) with no second code path to validate.
    """
    B, S = tokens.shape

    def body(carry, t):
        cache, _ = carry
        logits, cache = serve_step(params, cache, tokens[:, t][:, None],
                                   jnp.asarray(t),
                                   *([vis_embed] if vis_embed is not None else []))
        return (cache, logits), None

    (cache, logits), _ = jax.lax.scan(
        body, (cache, jnp.zeros((B, 1, cfg.vocab_size),
                                jnp.dtype(cfg.dtype))),
        jnp.arange(S))
    return logits, cache


def sample(key: jax.Array, logits: jax.Array, temperature: float) -> jax.Array:
    """(B,1,V) → (B,) next tokens."""
    logits = logits[:, -1].astype(jnp.float32)
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(key, logits / temperature).astype(jnp.int32)


@dataclasses.dataclass
class Request:
    uid: int
    prompt: list            # token ids
    max_new: int
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Server:
    """Slot-based continuous batching over the jitted decode step.

    Each of ``batch`` slots holds one request; finished slots are refilled
    from the queue without stopping the others (their pad-token steps are
    masked out).  This is the serving analogue of the learning engine's
    time-multiplexed neuron pipeline (§V-B) — one compiled step serves many
    logical streams.
    """

    def __init__(self, params: Params, cfg, serve_cfg: ServeConfig,
                 seed: int = 0):
        self.params = params
        self.cfg = cfg
        self.scfg = serve_cfg
        self.step_fn = jax.jit(make_serve_step(cfg, serve_cfg))
        self.key = jax.random.PRNGKey(seed)
        self.queue: list[Request] = []
        self.slots: list[Request | None] = [None] * serve_cfg.batch
        self.slot_pos = jnp.zeros((serve_cfg.batch,), jnp.int32)
        self.cache = init_cache(cfg, serve_cfg)
        self.completed: list[Request] = []

    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        for i, slot in enumerate(self.slots):
            if slot is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # per-slot prefill: feed prompt tokens one at a time
                pos = 0
                for t in req.prompt:
                    tok = jnp.full((self.scfg.batch, 1), 0, jnp.int32)
                    tok = tok.at[i, 0].set(t)
                    logits, self.cache = self.step_fn(
                        self.params, self.cache, tok, jnp.asarray(pos))
                    pos += 1
                self.slot_pos = self.slot_pos.at[i].set(pos)
                req._last_logits = logits[i]

    def run(self, max_steps: int = 256) -> list[Request]:
        """Drive all queued requests to completion (or max_steps)."""
        for _ in range(max_steps):
            self._admit()
            if all(s is None for s in self.slots):
                break
            toks = jnp.zeros((self.scfg.batch, 1), jnp.int32)
            for i, req in enumerate(self.slots):
                if req is not None:
                    logits = getattr(req, "_last_logits")
                    self.key, sub = jax.random.split(self.key)
                    nxt = sample(sub, logits[None], self.scfg.temperature)
                    req.out.append(int(nxt[0]))
                    toks = toks.at[i, 0].set(nxt[0])
            pos = int(jnp.max(self.slot_pos))
            logits, self.cache = self.step_fn(self.params, self.cache, toks,
                                              jnp.asarray(pos))
            self.slot_pos = self.slot_pos + jnp.asarray(
                [1 if s is not None else 0 for s in self.slots], jnp.int32)
            for i, req in enumerate(self.slots):
                if req is None:
                    continue
                req._last_logits = logits[i]
                if len(req.out) >= req.max_new:
                    req.done = True
                    self.completed.append(req)
                    self.slots[i] = None
        return self.completed
