"""Online-plasticity serving: batched continual-STDP steps over sessions.

Each request carries a spike raster for one user's private network; the
batched :func:`serve_step` gathers up to ``ServeConfig.max_batch``
admitted requests, rehydrates their sessions' packed word planes into
rule timing state (:meth:`repro.plasticity.UpdatePlan.session_state`),
runs them through the vmapped engine path with continual on-line STDP —
one compiled program per (config, learn) pair, always padded to
``max_batch`` lanes so the trace never respecializes — and scatters the
updated words, weights, membrane and θ back into the
:class:`~repro.serve.session.SessionStore`.

Determinism is the design invariant: lanes are independent (no
cross-lane reduction anywhere in the trace), so a session's trajectory
is bit-identical whether it is served solo or interleaved with others —
pinned by tests/test_serve.py and gated in CI via
``benchmarks/serve_cost.py``.  ``learn=False`` requests run the same
dynamics read-only (plasticity is omitted from the trace, nothing is
written back): eval traffic cannot perturb a user's learned state.

:class:`Server` is the async front end: ``submit``/``poll`` around a
deterministic FIFO batch admission rule (a batch is the longest queue
prefix with one ``learn`` flag and no repeated session — a session may
not ride two lanes of one batch), a background serving thread, and a
graceful ``shutdown(drain=True)`` that serves every queued request
before stopping.  Checkpoint/restore delegates to the store
(``repro.checkpoint``: atomic, checksummed).
"""

from __future__ import annotations

import dataclasses
import functools
import itertools
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro import plasticity
from repro.core.engine import EngineConfig, EngineState, engine_step
from repro.core.lif import LIFState
from repro.serve.session import SessionState, SessionStore


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Static serving knobs (hashable: baked into the compiled step).

    ``t_steps`` fixes every request raster's length — one compiled
    program serves all traffic.  ``theta_plus``/``theta_tau`` are the
    per-session homeostasis: each post spike raises that neuron's
    threshold θ by ``theta_plus`` and θ decays by ``exp(-1/theta_tau)``
    per step (0 disables, matching the unsupervised-training pipeline's
    adaptive threshold).  ``capacity`` bounds resident sessions (LRU).
    """

    max_batch: int = 8
    t_steps: int = 16
    theta_plus: float = 0.0
    theta_tau: float = 100.0
    capacity: int | None = None

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.t_steps < 1:
            raise ValueError(f"t_steps must be >= 1, got {self.t_steps}")
        if self.theta_tau <= 0:
            raise ValueError(f"theta_tau must be > 0, got {self.theta_tau}")

    @property
    def theta_decay(self) -> float:
        return float(np.exp(-1.0 / self.theta_tau))


@dataclasses.dataclass
class Request:
    """One unit of traffic: a (t_steps, n_pre) spike raster for ``sid``.

    ``learn=False`` marks eval traffic: the session's dynamics run on its
    current weights but nothing — weights, words, membrane, θ — is
    written back.
    """

    sid: str
    raster: Any               # (t_steps, n_pre) {0,1} spikes
    learn: bool = True


@dataclasses.dataclass
class Result:
    """Completed request: the session's post-spike raster for this slice."""

    sid: str
    ticket: int
    post: np.ndarray          # (t_steps, n_post) uint8 spikes
    learned: bool             # False: eval traffic, state not written back


@functools.partial(jax.jit, static_argnames=("cfg", "scfg", "learn"))
def _batched_rollout(cfg: EngineConfig, scfg: ServeConfig, learn: bool,
                     w, pre_words, post_words, v, theta, rasters):
    """vmapped engine rollout over ``max_batch`` independent sessions.

    All leading axes are the lane axis; lanes never interact (the
    bit-identity contract).  Returns the updated per-lane state leaves
    plus the post-spike rasters.
    """
    plan = plasticity.make_plan(cfg)
    decay = jnp.float32(scfg.theta_decay)
    theta_plus = jnp.float32(scfg.theta_plus)

    def one(w, pw, qw, v, th, x):
        state = EngineState(w, plan.session_state(pw),
                            plan.session_state(qw), LIFState(v))

        def step(carry, xt):
            s, th = carry
            s, out = engine_step(s, xt, cfg, learn=learn, v_th_offset=th)
            th = th * decay + theta_plus * out.astype(jnp.float32)
            return (s, th), out

        (state, th), post = jax.lax.scan(step, (state, th), x)
        return (state.w, plan.session_words(state.pre_hist),
                plan.session_words(state.post_hist), state.neurons.v, th,
                post.astype(jnp.uint8))

    return jax.vmap(one)(w, pre_words, post_words, v, theta, rasters)


def _stack(states: list[SessionState]):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *states)


def serve_step(store: SessionStore, requests: list[Request],
               scfg: ServeConfig, *, tickets: list[int] | None = None
               ) -> list[Result]:
    """Serve one admitted batch; scatter updated state back to the store.

    ``requests`` must already satisfy the admission invariants (≤
    ``max_batch``, one ``learn`` flag, unique sids) — :class:`Server`
    admits; direct callers get the same checks here.  Sessions absent
    from the store are initialized on first touch.  Dead lanes are padded
    with a template session so the compiled shape never changes.
    """
    if not requests:
        return []
    if len(requests) > scfg.max_batch:
        raise ValueError(f"batch of {len(requests)} exceeds "
                         f"max_batch={scfg.max_batch}")
    learn = requests[0].learn
    sids = [r.sid for r in requests]
    if len(set(sids)) != len(sids):
        raise ValueError(f"duplicate session in batch: {sids}")
    if any(r.learn != learn for r in requests):
        raise ValueError("mixed learn flags in one batch")

    cfg = store.cfg
    rasters = []
    for r in requests:
        x = jnp.asarray(r.raster, jnp.float32)
        if x.shape != (scfg.t_steps, cfg.n_pre):
            raise ValueError(f"request {r.sid!r}: raster shape {x.shape} != "
                             f"({scfg.t_steps}, {cfg.n_pre})")
        rasters.append(x)

    states = [store.get_or_init(sid) for sid in sids]
    pad = scfg.max_batch - len(requests)
    if pad:
        template = store.fresh_state("pad")
        states += [template] * pad
        rasters += [jnp.zeros((scfg.t_steps, cfg.n_pre), jnp.float32)] * pad

    stacked = _stack(states)
    w, pw, qw, v, theta, post = _batched_rollout(
        cfg, scfg, learn, stacked.w, stacked.pre_words, stacked.post_words,
        stacked.v, stacked.theta, jnp.stack(rasters))

    post = np.asarray(post)
    if tickets is None:
        tickets = list(range(len(requests)))
    results = []
    for i, (r, ticket) in enumerate(zip(requests, tickets)):
        if learn:
            store.put(r.sid, SessionState(
                w=w[i],
                pre_words=tuple(p[i] for p in pw),
                post_words=tuple(q[i] for q in qw),
                v=v[i], theta=theta[i],
                t=states[i].t + scfg.t_steps))
        results.append(Result(sid=r.sid, ticket=ticket, post=post[i],
                              learned=learn))
    return results


class Server:
    """Async submit/poll server over :func:`serve_step`.

    Single-consumer: batches are admitted and served either by the
    background thread (:meth:`start`) or by explicit :meth:`step` calls —
    the admission rule is deterministic in queue order, so both drives
    produce bit-identical results (pinned by the drain test).
    """

    def __init__(self, cfg: EngineConfig, scfg: ServeConfig, *,
                 seed: int = 0, store: SessionStore | None = None):
        self.scfg = scfg
        self.store = store if store is not None else SessionStore(
            cfg, capacity=scfg.capacity, seed=seed)
        self._tickets = itertools.count()
        self._queue: list[tuple[int, Request]] = []
        self._results: dict[int, Result] = {}
        self._lock = threading.Lock()
        self._work = threading.Condition(self._lock)
        self._thread: threading.Thread | None = None
        self._running = False

    @property
    def cfg(self) -> EngineConfig:
        return self.store.cfg

    # -- submit / poll --------------------------------------------------

    def submit(self, req: Request) -> int:
        """Enqueue a request; returns the ticket :meth:`poll` redeems."""
        with self._work:
            ticket = next(self._tickets)
            self._queue.append((ticket, req))
            self._work.notify()
        return ticket

    def poll(self, ticket: int) -> Result | None:
        """The finished :class:`Result`, or ``None`` while pending."""
        with self._lock:
            return self._results.pop(ticket, None)

    @property
    def pending(self) -> int:
        with self._lock:
            return len(self._queue)

    # -- batch admission + serving --------------------------------------

    def _admit(self) -> list[tuple[int, Request]]:
        """Pop the next batch (caller holds the lock).

        Deterministic FIFO prefix rule: the head request fixes the
        ``learn`` flag; the prefix extends while the flag matches, the
        session is not already aboard (two slices of one session in a
        single batch would race on its state), and ``max_batch`` lanes
        remain.
        """
        if not self._queue:
            return []
        learn = self._queue[0][1].learn
        batch: list[tuple[int, Request]] = []
        aboard: set[str] = set()
        for item in self._queue:
            _, req = item
            if len(batch) == self.scfg.max_batch:
                break
            if req.learn != learn or req.sid in aboard:
                break
            batch.append(item)
            aboard.add(req.sid)
        del self._queue[:len(batch)]
        return batch

    def step(self) -> int:
        """Admit and serve one batch synchronously; returns lanes served."""
        with self._lock:
            batch = self._admit()
        if not batch:
            return 0
        tickets = [t for t, _ in batch]
        results = serve_step(self.store, [r for _, r in batch], self.scfg,
                             tickets=tickets)
        with self._lock:
            for res in results:
                self._results[res.ticket] = res
        return len(results)

    def drain(self) -> int:
        """Serve until the queue is empty; returns total lanes served."""
        n = 0
        while True:
            served = self.step()
            if not served:
                return n
            n += served

    # -- async loop -----------------------------------------------------

    def start(self) -> None:
        """Start the background serving thread (idempotent)."""
        with self._lock:
            if self._running:
                return
            self._running = True
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self) -> None:
        while True:
            with self._work:
                while self._running and not self._queue:
                    self._work.wait()
                if not self._running:
                    return
            self.step()

    def shutdown(self, *, drain: bool = True) -> int:
        """Stop the loop; ``drain=True`` serves every queued request first.

        Returns the number of lanes served during the drain.  Safe to
        call whether or not :meth:`start` ever ran.
        """
        with self._work:
            self._running = False
            self._work.notify_all()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        return self.drain() if drain else 0

    # -- persistence ----------------------------------------------------

    def checkpoint(self, ckpt_dir: str, step: int | None = None) -> str:
        return self.store.checkpoint(ckpt_dir, step)

    def restore(self, ckpt_dir: str, step: int | None = None) -> None:
        self.store.restore(ckpt_dir, step)
