"""Per-user session state: the packed uint8 history word as a plasticity cache.

The paper's hardware claim (Figs. 3/11) is that ITP-STDP collapses all
per-synapse learning state into a 1-byte intrinsic-timing register per
neuron.  At serving time that makes continual on-line learning absurdly
cheap to keep resident per user: a session's *plasticity cache* is the
rule's packed word planes — one history word per neuron for the
intrinsic-timing rules, the history + eligibility pair (2 bytes) for
``mstdp``, one counter word for the Δt baselines — serialized and
rehydrated through :meth:`repro.plasticity.UpdatePlan.session_words` /
``session_state`` (the rules' own layouts are behind lint rule R8).

:class:`SessionStore` owns the id → :class:`SessionState` map with LRU
eviction under an optional capacity bound, the byte accounting
(``state_bytes_per_session`` prices the plasticity cache alone — the
number the paper's storage claim makes small — while
``resident_bytes_per_session`` adds the weights, membrane and θ a live
session also carries), and checkpoint/restore through
``repro.checkpoint`` (atomic, checksummed, session ids + LRU order in
the manifest's ``extra``).
"""

from __future__ import annotations

import zlib
from collections import OrderedDict
from typing import Iterator, NamedTuple

import jax
import jax.numpy as jnp

from repro import checkpoint as ckpt
from repro import plasticity
from repro.core.engine import EngineConfig


class SessionState(NamedTuple):
    """One user's resident state, word-serialized timing state included.

    ``pre_words`` / ``post_words`` are the rule's canonical uint8 word
    planes (the plasticity cache); ``w`` / ``v`` / ``theta`` are the
    synapse matrix, membrane potential, and adaptive-threshold θ of the
    session's private network; ``t`` counts simulation steps served.
    """

    w: jax.Array                      # float32[n_pre, n_post]
    pre_words: tuple[jax.Array, ...]  # uint8[n_pre] × words_per_neuron
    post_words: tuple[jax.Array, ...]  # uint8[n_post] × words_per_neuron
    v: jax.Array                      # float32[n_post] membrane
    theta: jax.Array                  # float32[n_post] adaptive threshold
    t: jax.Array                      # int32 scalar, steps served


class SessionStore:
    """LRU-bounded id → :class:`SessionState` map with byte accounting.

    ``capacity`` bounds the number of resident sessions; inserting a new
    session at capacity evicts the least-recently-used one.  ``get`` /
    ``put`` refresh recency; ``peek`` does not.  Session init is
    deterministic in (``seed``, session id), so a re-initialized session
    replays identically wherever it is created.
    """

    def __init__(self, cfg: EngineConfig, *, capacity: int | None = None,
                 seed: int = 0):
        if capacity is not None and capacity < 1:
            raise ValueError(f"capacity must be a positive session bound or "
                             f"None (unbounded), got {capacity}")
        self.cfg = cfg
        self.plan = plasticity.make_plan(cfg)
        self.capacity = capacity
        self.seed = seed
        self._sessions: OrderedDict[str, SessionState] = OrderedDict()

    # -- lifecycle ------------------------------------------------------

    def _key(self, sid: str) -> jax.Array:
        # stable across processes: fold the crc of the id into the seed
        return jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                  zlib.crc32(sid.encode()))

    def fresh_state(self, sid: str = "") -> SessionState:
        """A new session's state (weights keyed by ``(seed, sid)``)."""
        cfg = self.cfg
        w = jax.random.uniform(self._key(sid), (cfg.n_pre, cfg.n_post),
                               minval=0.2, maxval=0.8).astype(jnp.float32)
        return SessionState(
            w=w,
            pre_words=self.plan.init_words(cfg.n_pre),
            post_words=self.plan.init_words(cfg.n_post),
            v=jnp.full((cfg.n_post,), cfg.lif.e_rest, jnp.float32),
            theta=jnp.zeros((cfg.n_post,), jnp.float32),
            t=jnp.asarray(0, jnp.int32),
        )

    def init(self, sid: str) -> SessionState:
        """Create (or reset) ``sid``; evicts the LRU session at capacity."""
        if not sid or any(c in sid for c in "/\\\x00"):
            # sids become checkpoint leaf filenames — keep them path-safe
            raise ValueError(f"invalid session id {sid!r}")
        if sid in self._sessions:
            del self._sessions[sid]
        elif self.capacity is not None and len(self._sessions) >= self.capacity:
            self.evict()
        state = self.fresh_state(sid)
        self._sessions[sid] = state
        return state

    def get(self, sid: str) -> SessionState:
        """Fetch ``sid``'s state and mark it most recently used."""
        state = self._sessions[sid]
        self._sessions.move_to_end(sid)
        return state

    def get_or_init(self, sid: str) -> SessionState:
        return self.get(sid) if sid in self._sessions else self.init(sid)

    def peek(self, sid: str) -> SessionState:
        """Fetch without refreshing recency (eval/inspection reads)."""
        return self._sessions[sid]

    def put(self, sid: str, state: SessionState) -> None:
        """Write back an updated state and mark it most recently used."""
        self._sessions[sid] = state
        self._sessions.move_to_end(sid)

    def touch(self, sid: str) -> None:
        self._sessions.move_to_end(sid)

    def evict(self, sid: str | None = None) -> str:
        """Drop ``sid`` (default: the least-recently-used session)."""
        if sid is None:
            sid, _ = self._sessions.popitem(last=False)
            return sid
        del self._sessions[sid]
        return sid

    def __contains__(self, sid: str) -> bool:
        return sid in self._sessions

    def __len__(self) -> int:
        return len(self._sessions)

    def __iter__(self) -> Iterator[str]:
        return iter(self._sessions)

    @property
    def session_ids(self) -> tuple[str, ...]:
        """Resident ids, least recently used first."""
        return tuple(self._sessions)

    # -- byte accounting ------------------------------------------------

    def state_bytes_per_session(self) -> int:
        """Resident bytes of the plasticity cache alone: the packed word
        planes of both populations (1 byte/neuron/word).  This is the
        quantity the paper's 1-byte register claim bounds — CI gates it
        at ≤ 2 bytes/neuron (history word + eligibility word)."""
        n = self.cfg.n_pre + self.cfg.n_post
        return n * self.plan.words_per_neuron()

    def resident_bytes_per_session(self) -> int:
        """Everything a session keeps resident: plasticity cache plus the
        float32 synapse matrix, membrane, θ, and the step counter."""
        cfg = self.cfg
        return (self.state_bytes_per_session()
                + 4 * cfg.n_pre * cfg.n_post      # w
                + 4 * cfg.n_post                  # v
                + 4 * cfg.n_post                  # theta
                + 4)                              # t

    def sessions_per_gb(self, *, resident: bool = False) -> float:
        """How many sessions fit per GiB of host memory.

        ``resident=False`` prices the plasticity cache alone (the paper's
        headline: a 10k-neuron net is ~10 KB/session); ``resident=True``
        includes the session's weights and neuron state.
        """
        per = (self.resident_bytes_per_session() if resident
               else self.state_bytes_per_session())
        return float(1 << 30) / per

    # -- checkpoint / restore -------------------------------------------

    def checkpoint(self, ckpt_dir: str, step: int | None = None) -> str:
        """Atomic checksummed save of every resident session.

        The tree is ``{sid: SessionState}``; session ids, LRU order, and
        the config/rule fingerprint ride in the manifest's ``extra`` so
        :meth:`restore` can rebuild its target without out-of-band state.
        """
        if step is None:
            step = len(ckpt.list_checkpoints(ckpt_dir))
        extra = {
            "sessions": list(self._sessions),   # LRU order, oldest first
            "rule": self.cfg.rule,
            "n_pre": self.cfg.n_pre,
            "n_post": self.cfg.n_post,
            "depth": self.cfg.depth,
        }
        return ckpt.save_checkpoint(ckpt_dir, step, dict(self._sessions),
                                    extra=extra)

    def restore(self, ckpt_dir: str, step: int | None = None) -> None:
        """Replace the resident map with a checkpoint's sessions.

        Restores in the saved LRU order (recency survives the round
        trip); checksums are verified leaf-by-leaf by ``repro.checkpoint``.
        """
        if step is None:
            step = ckpt.latest_checkpoint(ckpt_dir)
            if step is None:
                raise FileNotFoundError(f"no checkpoints under {ckpt_dir!r}")
        extra = ckpt.load_manifest(ckpt_dir, step)["extra"]
        for field in ("rule", "n_pre", "n_post", "depth"):
            have = getattr(self.cfg, field)
            saved = extra[field]
            if saved != have:
                raise ValueError(f"checkpoint {field}={saved!r} does not match "
                                 f"store config {field}={have!r}")
        sids = extra["sessions"]
        target = {sid: self.fresh_state(sid) for sid in sids}
        restored = ckpt.restore_checkpoint(ckpt_dir, step, target)
        self._sessions = OrderedDict((sid, restored[sid]) for sid in sids)
