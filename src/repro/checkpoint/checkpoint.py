"""Fault-tolerant sharded checkpointing.

Layout (one directory per step, atomically renamed into place):

    <dir>/step_000123/
        manifest.json       # step, leaf paths, shapes, dtypes, sha256s
        <leaf-path>.npy     # one file per pytree leaf

Design points for 1000+-node deployments (scaled down to one process here):
  * **atomic commit** — writes land in ``step_N.tmp`` and are renamed only
    after the manifest (written last) is fsync'd; a crash mid-save leaves
    the previous checkpoint intact and the partial dir is ignored/cleaned.
  * **integrity** — every leaf carries a sha256; restore verifies before
    any data reaches the model, so a torn write surfaces as a clean error
    and ``latest_checkpoint`` falls back to the previous valid step.
  * **async save** — ``AsyncCheckpointer`` snapshots device arrays to host
    then writes on a background thread; the train loop blocks only for the
    device→host copy (the same contract as Orbax async).
  * **elastic restore** — leaves are saved unsharded (full logical arrays);
    ``restore_checkpoint`` re-shards onto whatever mesh the *new* job
    brings up, so a restart may change DP width (elastic resize) or pod
    count.  At real scale the npy-per-leaf files become per-shard files
    keyed by PartitionSpec; the manifest schema already records shardings.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

Pytree = Any


def _leaf_paths(tree: Pytree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for k in path:
            if hasattr(k, "key"):
                parts.append(str(k.key))
            elif hasattr(k, "idx"):
                parts.append(str(k.idx))
            else:
                parts.append(str(k).strip("[]'"))
        out.append(("__".join(parts) or "leaf", leaf))
    return out


def _sha256(arr: np.ndarray) -> str:
    return hashlib.sha256(np.ascontiguousarray(arr).tobytes()).hexdigest()


def save_checkpoint(ckpt_dir: str, step: int, tree: Pytree,
                    extra: dict | None = None) -> str:
    """Synchronous atomic save; returns the committed directory."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:09d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)

    manifest = {"step": step, "leaves": [], "extra": extra or {}}
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        np.save(os.path.join(tmp, name + ".npy"), arr)
        manifest["leaves"].append({
            "name": name, "shape": list(arr.shape), "dtype": str(arr.dtype),
            "sha256": _sha256(arr),
        })
    mpath = os.path.join(tmp, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=1)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def load_manifest(ckpt_dir: str, step: int) -> dict:
    """The committed manifest of one step — leaf metadata plus ``extra``.

    Restore-side callers that need the saver's ``extra`` payload *before*
    they can build a restore target read it from here (e.g. the serving
    ``SessionStore``, whose checkpoint tree is keyed by the session ids
    recorded in ``extra``); the leaf data itself still round-trips through
    :func:`restore_checkpoint` so every checksum is verified.
    """
    path = os.path.join(ckpt_dir, f"step_{step:09d}", "manifest.json")
    with open(path) as f:
        return json.load(f)


def list_checkpoints(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                steps.append(int(d[len("step_"):]))
    return sorted(steps)


def latest_checkpoint(ckpt_dir: str) -> int | None:
    steps = list_checkpoints(ckpt_dir)
    return steps[-1] if steps else None


def _verify_and_load(path: str, meta: dict) -> np.ndarray:
    arr = np.load(os.path.join(path, meta["name"] + ".npy"))
    if list(arr.shape) != meta["shape"] or str(arr.dtype) != meta["dtype"]:
        raise IOError(f"checkpoint leaf {meta['name']}: shape/dtype mismatch")
    if _sha256(arr) != meta["sha256"]:
        raise IOError(f"checkpoint leaf {meta['name']}: checksum mismatch "
                      "(torn or corrupted write)")
    return arr


def restore_checkpoint(ckpt_dir: str, step: int, target: Pytree,
                       shardings: Pytree | None = None) -> Pytree:
    """Restore into the structure of ``target``; verify checksums.

    ``shardings``: optional pytree of NamedShardings (same structure) —
    the elastic-restore path: arrays are placed directly onto the new
    mesh regardless of the mesh geometry at save time.
    """
    path = os.path.join(ckpt_dir, f"step_{step:09d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_name = {m["name"]: m for m in manifest["leaves"]}

    named = _leaf_paths(target)
    flat_target, treedef = jax.tree_util.tree_flatten(target)
    shard_flat = (jax.tree_util.tree_leaves(shardings)
                  if shardings is not None else [None] * len(flat_target))

    out = []
    for (name, tgt), sh in zip(named, shard_flat):
        if name not in by_name:
            raise IOError(f"checkpoint missing leaf {name}")
        arr = _verify_and_load(path, by_name[name])
        if sh is not None:
            out.append(jax.device_put(arr, sh))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)


def prune_checkpoints(ckpt_dir: str, keep: int = 3) -> None:
    steps = list_checkpoints(ckpt_dir)
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:09d}"),
                      ignore_errors=True)


class AsyncCheckpointer:
    """Background-thread checkpoint writer with at-most-one in flight.

    ``save`` snapshots to host synchronously (cheap) and enqueues the disk
    write; a second ``save`` while one is in flight blocks until the first
    commits (backpressure instead of unbounded queueing — same policy as
    production checkpointers).
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self._error: Exception | None = None

    def save(self, step: int, tree: Pytree, extra: dict | None = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            try:
                save_checkpoint(self.ckpt_dir, step, host_tree, extra)
                prune_checkpoints(self.ckpt_dir, self.keep)
            except Exception as e:  # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err
