from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_checkpoint,
                                         list_checkpoints, prune_checkpoints,
                                         restore_checkpoint, save_checkpoint)
