from repro.checkpoint.checkpoint import (AsyncCheckpointer, latest_checkpoint,
                                         list_checkpoints, load_manifest,
                                         prune_checkpoints, restore_checkpoint,
                                         save_checkpoint)
