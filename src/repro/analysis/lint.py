"""Combined lint runner: AST rules R1–R6 + the R7 import graph."""
from __future__ import annotations

from pathlib import Path
from typing import Iterable

from repro.analysis.astlint import AST_RULES, Finding, run_ast_rules
from repro.analysis.importgraph import run_import_graph

ALL_RULES = tuple(AST_RULES) + ("R7",)


def run_lint(root: Path, rules: Iterable[str] = ()) -> list[Finding]:
    rules = tuple(rules or ALL_RULES)
    unknown = set(rules) - set(ALL_RULES)
    if unknown:
        raise ValueError(f"unknown rules {sorted(unknown)}; have {ALL_RULES}")
    ast_rules = [r for r in rules if r in AST_RULES]
    findings = run_ast_rules(root, ast_rules)
    if "R7" in rules:
        findings += run_import_graph(root)
    return sorted(findings)
