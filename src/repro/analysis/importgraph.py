"""R7 — static import-graph reachability (dead-code report).

Builds the module map for everything under ``src/`` and walks the static
import edges from the entry-point surfaces: ``repro.launch.*`` (the CLI),
plus every script/module under ``benchmarks/``, ``examples/``,
``tools/`` and ``tests/``.  Anything under ``src/`` not reached is an
orphan finding keyed by *module name* (the allowlist records known
orphans — e.g. the LM arch configs loaded via ``importlib`` strings —
with a justification each).

Conservative choices: ``from pkg import name`` marks ``pkg`` and, when
``pkg.name`` is a known module, that module too; importing any module
marks its ancestor packages (their ``__init__`` executes on import);
dynamic ``importlib`` loads are *not* followed — that is the point of
the tracked baseline.
"""
from __future__ import annotations

import ast
from pathlib import Path

from repro.analysis.astlint import Finding, iter_source_files

ENTRY_PACKAGES = ("repro.launch",)
ENTRY_DIRS = ("benchmarks", "examples", "tools", "tests")


def module_name(rel: str) -> str | None:
    """'src/repro/core/engine.py' → 'repro.core.engine' (None if not src)."""
    if not rel.startswith("src/") or not rel.endswith(".py"):
        return None
    parts = rel[len("src/") : -len(".py")].split("/")
    if parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _imports_of(tree: ast.AST, self_pkg: str) -> set[str]:
    """Absolute dotted names a module's import statements mention.

    ``self_pkg`` is the importing module's package (``a.b`` for module
    ``a.b.c`` or package ``a.b`` itself) — the anchor for relative
    imports: level N strips N-1 trailing components from it.
    """
    out: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out.add(a.name)
        elif isinstance(node, ast.ImportFrom):
            if node.level == 0:
                base = node.module or ""
            else:
                parts = self_pkg.split(".") if self_pkg else []
                keep = max(0, len(parts) - (node.level - 1))
                base = ".".join(parts[:keep])
                if node.module:
                    base = f"{base}.{node.module}" if base else node.module
            if base:
                out.add(base)
                for a in node.names:
                    out.add(f"{base}.{a.name}")
    return out


def _ancestors(mod: str) -> list[str]:
    parts = mod.split(".")
    return [".".join(parts[:i]) for i in range(1, len(parts) + 1)]


def _is_entry_module(mod: str) -> bool:
    return any(mod == pkg or mod.startswith(pkg + ".") for pkg in ENTRY_PACKAGES)


def run_import_graph(root: Path) -> list[Finding]:
    """Return one R7 finding per orphan module under ``src/``."""
    files = iter_source_files(root)
    modules: dict[str, str] = {}  # module name → relpath
    trees: dict[str, ast.AST] = {}  # relpath → parsed tree
    for p in files:
        rel = p.relative_to(root).as_posix()
        try:
            trees[rel] = ast.parse(p.read_text(), filename=rel)
        except SyntaxError:
            continue  # reported by the AST layer
        mod = module_name(rel)
        if mod:
            modules[mod] = rel

    entry_rels = [rel for rel in trees if rel.split("/")[0] in ENTRY_DIRS]
    entry_mods = [m for m in modules if _is_entry_module(m)]

    reachable: set[str] = set()
    queue: list[str] = []

    def mark(dotted: str) -> None:
        for anc in _ancestors(dotted):
            if anc in modules and anc not in reachable:
                reachable.add(anc)
                queue.append(anc)

    def pkg_of(mod: str) -> str:
        if modules[mod].endswith("__init__.py"):
            return mod
        return mod.rpartition(".")[0]

    for m in entry_mods:
        mark(m)
    for rel in entry_rels:
        for d in _imports_of(trees[rel], ""):
            mark(d)
    while queue:
        mod = queue.pop()
        tree = trees.get(modules[mod])
        if tree is None:
            continue
        for d in _imports_of(tree, pkg_of(mod)):
            mark(d)

    out = []
    for mod in sorted(set(modules) - reachable):
        msg = f"module `{mod}` unreachable from any entry point (see --explain R7)"
        out.append(Finding("R7", modules[mod], 1, msg, mod))
    return sorted(out)
