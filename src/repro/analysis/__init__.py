"""repro-lint: static analysis enforcing the repo's hardware contracts.

Two layers (ROADMAP "Invariants (machine-checked)"):

* :mod:`repro.analysis.astlint` + :mod:`repro.analysis.importgraph` —
  AST-level rules R1–R7 over the source tree (no imports executed).
* :mod:`repro.analysis.jaxpr_audit` — traces every valid rule × backend
  × layer-kind cell of the ROADMAP matrix abstractly and checks the
  jaxprs against the paper's dataflow contracts (uint8 operands, no
  float64, static shapes), recording a host-independent primitive-count
  fingerprint.

Plus the documentation layer, :mod:`repro.analysis.doclint` (rules
D1/D2): fenced ```python snippets in README.md/docs/ must execute and
intra-repo links must resolve (``python -m tools.check --docs``).

Driven by ``python -m tools.check``; the committed baseline lives in
``tools/check_allowlist.json`` and only ever ratchets down.
"""
from repro.analysis.allowlist import apply_allowlist, load_allowlist, render_allowlist
from repro.analysis.astlint import AST_RULES, RULE_EXPLAIN, Finding, run_ast_rules
from repro.analysis.doclint import DOC_RULE_EXPLAIN, run_doclint
from repro.analysis.importgraph import run_import_graph
from repro.analysis.lint import ALL_RULES, run_lint

__all__ = [
    "ALL_RULES",
    "AST_RULES",
    "DOC_RULE_EXPLAIN",
    "RULE_EXPLAIN",
    "Finding",
    "apply_allowlist",
    "load_allowlist",
    "render_allowlist",
    "run_ast_rules",
    "run_doclint",
    "run_import_graph",
    "run_lint",
]
