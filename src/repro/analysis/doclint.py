"""doc-lint: executable documentation, checked like code.

Two rules over the repo's markdown layer (README.md + docs/):

* **D1 — snippets execute.**  Every fenced ```` ```python ```` block is
  run in a subprocess from the repo root with ``PYTHONPATH=src``; a
  non-zero exit is a finding.  Docs drift silently the moment an API they
  quote changes shape — executing them turns every rename into a CI
  failure instead of a confused reader.  Blocks that legitimately cannot
  run standalone (pseudo-code, shell-flavoured fragments) should be
  fenced as ``text``/``bash``/plain instead of ``python``; the fence
  language is the opt-in.
* **D2 — intra-repo links resolve.**  Every inline markdown link whose
  target is a relative path (no scheme, no ``#``-only anchor) must exist
  relative to the linking file.  Anchors on existing files are not
  checked (heading slugs are renderer-specific); external URLs are out
  of scope.

Run via ``python -m tools.check --docs`` (included in ``--all``).  Kept
out of the ``run_lint`` AST layer on purpose: these rules execute
documentation (D1 spawns interpreters), while R1–R8 are pure
source-tree analysis that must stay import-free and fast.
"""

from __future__ import annotations

import os
import re
import subprocess
import sys
from pathlib import Path

from repro.analysis.astlint import Finding

DOC_GLOBS = ("README.md", "docs/*.md")
SNIPPET_TIMEOUT_S = 120

_FENCE_RE = re.compile(r"^```(\w*)\s*$")
# inline links only; reference-style and images share the (...) target form
_LINK_RE = re.compile(r"\[[^\]^\[]*\]\(([^)\s]+)\)")


def doc_files(root: Path) -> list[Path]:
    out: list[Path] = []
    for pattern in DOC_GLOBS:
        out.extend(sorted(root.glob(pattern)))
    return [p for p in out if p.is_file()]


def python_snippets(text: str) -> list[tuple[int, str]]:
    """(start_line, source) for every fenced ```python block."""
    snippets = []
    lines = text.splitlines()
    i = 0
    while i < len(lines):
        m = _FENCE_RE.match(lines[i])
        if m and m.group(1) == "python":
            start = i + 2  # 1-based line of the snippet's first line
            body = []
            i += 1
            while i < len(lines) and not lines[i].startswith("```"):
                body.append(lines[i])
                i += 1
            snippets.append((start, "\n".join(body)))
        i += 1
    return snippets


def check_snippets(root: Path, path: Path) -> list[Finding]:
    """D1: every ```python fence in ``path`` must run clean."""
    findings = []
    rel = path.relative_to(root).as_posix()
    env = dict(os.environ)
    env["PYTHONPATH"] = str(root / "src") + os.pathsep + env.get("PYTHONPATH", "")
    for line, src in python_snippets(path.read_text()):
        try:
            proc = subprocess.run(
                [sys.executable, "-c", src],
                cwd=root,
                env=env,
                capture_output=True,
                text=True,
                timeout=SNIPPET_TIMEOUT_S,
            )
        except subprocess.TimeoutExpired:
            findings.append(
                Finding("D1", rel, line, f"snippet timed out after {SNIPPET_TIMEOUT_S}s")
            )
            continue
        if proc.returncode != 0:
            tail = (proc.stderr or proc.stdout).strip().splitlines()
            detail = tail[-1] if tail else f"exit {proc.returncode}"
            findings.append(Finding("D1", rel, line, f"snippet failed: {detail}"))
    return findings


def check_links(root: Path, path: Path) -> list[Finding]:
    """D2: relative link targets must exist on disk."""
    findings = []
    rel = path.relative_to(root).as_posix()
    for lineno, line in enumerate(path.read_text().splitlines(), start=1):
        for target in _LINK_RE.findall(line):
            if "://" in target or target.startswith(("#", "mailto:")):
                continue
            dest = target.split("#", 1)[0]
            if not dest:
                continue
            if not (path.parent / dest).exists():
                findings.append(Finding("D2", rel, lineno, f"broken link target {target!r}"))
    return findings


def run_doclint(root: Path, *, execute: bool = True) -> list[Finding]:
    """All doc findings; ``execute=False`` skips D1 (link-check only)."""
    findings: list[Finding] = []
    for path in doc_files(root):
        findings.extend(check_links(root, path))
        if execute:
            findings.extend(check_snippets(root, path))
    return sorted(findings)


DOC_RULE_EXPLAIN = {
    "D1": (
        "D1: every ```python fence in README.md/docs/ must execute "
        "clean from the repo root (PYTHONPATH=src). Fence non-runnable "
        "fragments as text/bash instead."
    ),
    "D2": (
        "D2: relative markdown link targets in README.md/docs/ must "
        "exist on disk (anchors and external URLs are not checked)."
    ),
}
