"""AST lint rules R1–R6 + R8: per-file checkers over parsed source, no imports.

Each rule is a pure function ``(tree, relpath) → [Finding]`` plus a path
predicate saying where it applies; :func:`run_ast_rules` walks a source
tree (the repo, or a fixture tree mirroring its layout — the predicates
only look at *relative* paths, so the checker is testable against
``tests/fixtures/lint/``) and concatenates the findings.

The rules encode the paper's hardware contracts as code invariants — see
``RULE_EXPLAIN`` (surfaced by ``python -m tools.check --explain <rule>``)
for the rationale of each.
"""
from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Callable, Iterable

# directories never scanned, wherever they appear
_SKIP_DIRS = {"__pycache__", ".git", "experiments", "fixtures"}

# top-level directories that make up the scanned source tree
SCAN_ROOTS = ("src", "benchmarks", "tools", "examples", "tests")


@dataclasses.dataclass(frozen=True, order=True)
class Finding:
    """One rule violation.

    ``obj`` is the allowlist match key — the relative file path for the
    AST rules, the module name for R7.
    """

    rule: str
    path: str  # posix-style path relative to the scanned root
    line: int
    message: str
    obj: str = ""

    def key(self) -> str:
        return self.obj or self.path

    def render(self) -> str:
        return f"{self.rule} {self.path}:{self.line} {self.message}"


RULE_EXPLAIN = {
    "R1": """\
R1: `shard_map` may only be touched inside repro/distributed/sharding.py.
The pinned jax (0.4.37) has no `jax.shard_map`; newer toolchains deprecate
`jax.experimental.shard_map` and change the manual-axes keywords
(`auto=` vs `axis_names=`/`check_vma=`).  `shard_map_compat` in
repro/distributed/sharding.py is the single version shim — every other
reference to the raw name is a latent AttributeError on one toolchain or
the other (train_step.py:129 shipped exactly that bug).""",
    "R2": """\
R2: `repro.kernels.itp_*` packages are importable only by the plasticity
rules and by kernel packages themselves.
The learning rules own their datapaths: engines, models and launchers
select a kernel through the rule hooks (`fused_update_from_readout`,
`sparse_update_from_readout`, ...) and `kernels.dispatch`, never by
reaching into a kernel package.  A direct import hard-wires one rule
family's layout into a consumer and breaks the rule × backend matrix.
Rule-neutral helpers (event lists, im2col) re-export from
`repro.kernels.dispatch` — import them from there.""",
    "R3": """\
R3: no literal `interpret=True/False` defaults in kernel ops wrappers.
`interpret` must default to None and resolve via
`dispatch.default_interpret()`: the Pallas interpreter is a CPU-only
fallback, and a baked-in `True` silently runs the interpreter on real
accelerators (a silent orders-of-magnitude slowdown), while a baked-in
`False` crashes CPU CI.  Applies to `src/repro/kernels/**/ops.py` — the
public wrappers; `kernel.py` internals receive the resolved flag.""",
    "R4": """\
R4: one-argument `jnp.where(mask)` requires a static `size=`.
Without `size`, the result shape depends on runtime data, which fails
under jit and contradicts the paper's fixed-capacity event queues — the
hardware has a static number of event slots per step.  Use
`jnp.where(mask, size=cap, fill_value=n)` (the itp_sparse.events
pattern) so event extraction stays a static-shape operation.""",
    "R5": """\
R5: test modules import `_hypothesis_compat`, never `hypothesis` directly.
CI runs the suite both with and without hypothesis installed; the compat
shim degrades property tests to single-example runs when the package is
absent.  A direct `import hypothesis` makes the whole module un-collectable
in the minimal environment.""",
    "R6": """\
R6: benchmarks write tracked BENCH_*.json via `bench_io.update_bench_json`.
The tracked BENCH files are merged read-modify-write artifacts shared by
every benchmark module and diffed by CI; a raw `json.dump`/`open(...,"w")`
of a BENCH_ path clobbers the other modules' sections and races parallel
writers.  Per-run outputs under the experiment out-dir are fine — the
rule only fires on BENCH_-prefixed paths.""",
    "R7": """\
R7: every module under src/repro must be statically reachable from an
entry point (repro.launch.*, examples/, benchmarks/, tools/, tests/).
Unreachable modules are dead code that still bit-rots against the moving
APIs and silently escapes every test tier.  The tracked baseline lists
the known orphans (e.g. the dynamically-imported LM arch configs) with a
justification each; the list may only shrink.""",
    "R8": """\
R8: rule datapath hooks are called only inside repro/plasticity/.
`kernel_readout` / `kernel_readout_axes` / `magnitudes_from_readout`,
the `*_from_readout` hooks, and the session word-serialization pair
(`serve_words` / `state_from_words`) are the LearningRule ↔ kernel/store
seam; engines, models, launchers, the serving layer, benchmarks and
tests dispatch through the `plasticity.apply` layer (`make_plan` /
`UpdatePlan` / `apply_update`), which owns backend resolution,
packed-vs-unpacked readout selection and the dense / conv / sharded /
session shape variants exactly once.  A direct hook call re-creates the
per-consumer branch sprawl the dispatch layer collapsed and silently
skips plan-level invariants (the silent-step skip, event-list capping,
readout layout selection).""",
}


def _dotted(node: ast.AST) -> str | None:
    """'jnp.where' for Attribute(Name) chains; None for anything else."""
    parts: list[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


# ---------------------------------------------------------------------------
# R1 — shard_map only inside the compat shim
# ---------------------------------------------------------------------------


def _applies_r1(relpath: str) -> bool:
    return relpath != "src/repro/distributed/sharding.py"


def _check_r1(tree: ast.AST, relpath: str) -> list[Finding]:
    out = []
    for node in ast.walk(tree):
        hit = None
        if isinstance(node, ast.Attribute) and node.attr == "shard_map":
            hit = f"`{_dotted(node) or '...shard_map'}`"
        elif isinstance(node, ast.Name) and node.id == "shard_map":
            hit = "`shard_map`"
        elif isinstance(node, ast.ImportFrom):
            names = [a.name for a in node.names]
            if (node.module or "").split(".")[-1] == "shard_map" or "shard_map" in names:
                hit = f"import from `{node.module}`"
        elif isinstance(node, ast.Import):
            for a in node.names:
                if "shard_map" in a.name.split("."):
                    hit = f"`import {a.name}`"
        if hit:
            msg = f"{hit} outside repro/distributed/sharding.py — use shard_map_compat"
            out.append(Finding("R1", relpath, node.lineno, msg, relpath))
    return out


# ---------------------------------------------------------------------------
# R2 — kernel packages only via rule hooks / dispatch re-exports
# ---------------------------------------------------------------------------


def _applies_r2(relpath: str) -> bool:
    if not relpath.startswith("src/repro/"):
        return False
    return not relpath.startswith(("src/repro/kernels/", "src/repro/plasticity/"))


def _is_itp_import(module: str, names: Iterable[str] = ()) -> bool:
    if module.startswith("repro.kernels.itp_"):
        return True
    return module == "repro.kernels" and any(n.startswith("itp_") for n in names)


def _check_r2(tree: ast.AST, relpath: str) -> list[Finding]:
    out = []
    for node in ast.walk(tree):
        bad = None
        if isinstance(node, ast.Import):
            for a in node.names:
                if _is_itp_import(a.name):
                    bad = a.name
        elif isinstance(node, ast.ImportFrom) and node.level == 0:
            mod = node.module or ""
            if _is_itp_import(mod, [a.name for a in node.names]):
                bad = mod
        if bad:
            msg = f"direct kernel-package import `{bad}` — use rule hooks or kernels.dispatch"
            out.append(Finding("R2", relpath, node.lineno, msg, relpath))
    return out


# ---------------------------------------------------------------------------
# R3 — no literal interpret defaults in ops wrappers
# ---------------------------------------------------------------------------


def _applies_r3(relpath: str) -> bool:
    return relpath.startswith("src/repro/kernels/") and relpath.endswith("/ops.py")


def _check_r3(tree: ast.AST, relpath: str) -> list[Finding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            continue
        a = node.args
        pairs = list(zip(a.kwonlyargs, a.kw_defaults))
        pos = a.posonlyargs + a.args
        n_no_default = len(pos) - len(a.defaults)
        pairs += list(zip(pos[n_no_default:], a.defaults))
        for arg, default in pairs:
            if arg.arg != "interpret":
                continue
            if not (isinstance(default, ast.Constant) and isinstance(default.value, bool)):
                continue
            msg = f"`{node.name}` defaults interpret={default.value} — default to None instead"
            out.append(Finding("R3", relpath, default.lineno, msg, relpath))
    return out


# ---------------------------------------------------------------------------
# R4 — one-arg jnp.where needs a static size
# ---------------------------------------------------------------------------


def _applies_r4(relpath: str) -> bool:
    return relpath.startswith("src/repro/")


def _check_r4(tree: ast.AST, relpath: str) -> list[Finding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        if _dotted(node.func) not in ("jnp.where", "jax.numpy.where"):
            continue
        if len(node.args) != 1:
            continue  # 3-arg select form: static shape
        if any(kw.arg == "size" for kw in node.keywords):
            continue
        msg = "one-arg jnp.where without size= — pass size=cap, fill_value=n"
        out.append(Finding("R4", relpath, node.lineno, msg, relpath))
    return out


# ---------------------------------------------------------------------------
# R5 — tests go through the hypothesis compat shim
# ---------------------------------------------------------------------------


def _applies_r5(relpath: str) -> bool:
    return relpath.startswith("tests/") and not relpath.endswith("_hypothesis_compat.py")


def _check_r5(tree: ast.AST, relpath: str) -> list[Finding]:
    out = []
    for node in ast.walk(tree):
        bad = None
        if isinstance(node, ast.Import):
            for a in node.names:
                if a.name == "hypothesis" or a.name.startswith("hypothesis."):
                    bad = a.name
        elif isinstance(node, ast.ImportFrom) and node.level == 0:
            mod = node.module or ""
            if mod == "hypothesis" or mod.startswith("hypothesis."):
                bad = mod
        if bad:
            msg = f"direct `{bad}` import — go through _hypothesis_compat"
            out.append(Finding("R5", relpath, node.lineno, msg, relpath))
    return out


# ---------------------------------------------------------------------------
# R6 — tracked BENCH files only via bench_io
# ---------------------------------------------------------------------------


def _applies_r6(relpath: str) -> bool:
    return relpath.startswith("benchmarks/") and not relpath.endswith("bench_io.py")


def _bench_literal(node: ast.AST) -> bool:
    for n in ast.walk(node):
        is_str = isinstance(n, ast.Constant) and isinstance(n.value, str)
        if is_str and n.value.startswith("BENCH_"):
            return True
    return False


def _opens_for_write(node: ast.Call) -> bool:
    modes = list(node.args[1:2]) + [kw.value for kw in node.keywords if kw.arg == "mode"]
    for m in modes:
        if isinstance(m, ast.Constant) and isinstance(m.value, str) and set(m.value) & set("wax"):
            return True
    return False


def _check_r6(tree: ast.AST, relpath: str) -> list[Finding]:
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        name = _dotted(node.func)
        if name in ("json.dump", "json.dumps") and _bench_literal(node):
            msg = f"`{name}` targeting a BENCH_ file — use bench_io.update_bench_json"
            out.append(Finding("R6", relpath, node.lineno, msg, relpath))
        elif name == "open" and _bench_literal(node) and _opens_for_write(node):
            msg = "`open` of a BENCH_ file for writing — use bench_io.update_bench_json"
            out.append(Finding("R6", relpath, node.lineno, msg, relpath))
    return out


# ---------------------------------------------------------------------------
# R8 — rule datapath hooks only inside the plasticity dispatch layer
# ---------------------------------------------------------------------------

# the LearningRule ↔ kernel seam: the readout views, every
# *_from_readout datapath hook, and the session word-serialization pair
# the serving layer's per-user state rides on (see repro/plasticity/base.py)
_R8_HOOKS = frozenset({
    "kernel_readout",
    "kernel_readout_axes",
    "magnitudes_from_readout",
    "fused_update_from_readout",
    "fused_delta_from_readout",
    "conv_delta_from_readout",
    "sparse_update_from_readout",
    "sparse_delta_from_readout",
    "sparse_conv_delta_from_readout",
    "serve_words",
    "state_from_words",
})


def _applies_r8(relpath: str) -> bool:
    return not relpath.startswith("src/repro/plasticity/")


def _check_r8(tree: ast.AST, relpath: str) -> list[Finding]:
    # syntactic and receiver-agnostic (like R4): any `<expr>.<hook>(...)`
    # call site counts — defining a hook *method* on a rule class is fine,
    # calling one outside the dispatch layer is not
    out = []
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        if isinstance(func, ast.Attribute) and func.attr in _R8_HOOKS:
            msg = (f"rule hook `.{func.attr}(...)` outside repro/plasticity/ "
                   f"— dispatch through plasticity.apply (make_plan/UpdatePlan)")
            out.append(Finding("R8", relpath, node.lineno, msg, relpath))
    return out


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


AST_RULES: dict[str, tuple[Callable[[str], bool], Callable[[ast.AST, str], list[Finding]]]] = {
    "R1": (_applies_r1, _check_r1),
    "R2": (_applies_r2, _check_r2),
    "R3": (_applies_r3, _check_r3),
    "R4": (_applies_r4, _check_r4),
    "R5": (_applies_r5, _check_r5),
    "R6": (_applies_r6, _check_r6),
    "R8": (_applies_r8, _check_r8),
}


def iter_source_files(root: Path) -> list[Path]:
    files = []
    for top in SCAN_ROOTS:
        base = root / top
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            rel = p.relative_to(root)
            if any(part in _SKIP_DIRS or part.startswith(".") for part in rel.parts):
                continue
            files.append(p)
    return files


def run_ast_rules(root: Path, rules: Iterable[str] | None = None) -> list[Finding]:
    """Run the AST rules (None = all of R1–R6 + R8) over the tree at ``root``."""
    selected = {r: AST_RULES[r] for r in (AST_RULES if rules is None else rules)}
    findings: list[Finding] = []
    for path in iter_source_files(root):
        rel = path.relative_to(root).as_posix()
        applicable = {r: chk for r, (pred, chk) in selected.items() if pred(rel)}
        if not applicable:
            continue
        try:
            tree = ast.parse(path.read_text(), filename=rel)
        except SyntaxError as e:
            findings.append(Finding("PARSE", rel, e.lineno or 0, f"syntax error: {e.msg}", rel))
            continue
        for check in applicable.values():
            findings.extend(check(tree, rel))
    return sorted(findings)
