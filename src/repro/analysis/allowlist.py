"""Allowlisted-baseline handling: the gate ratchets down, never up.

``tools/check_allowlist.json`` maps each rule to a list of entries:

* R1–R6 — ``{"file": "<repo-relative path>", "justification": "..."}``
* R7    — ``{"module": "<dotted module>", "justification": "..."}``

:func:`apply_allowlist` splits a finding list into NEW findings (not in
the baseline → fail) and reports STALE entries (baselined but no longer
found → fail too, so the file has to shrink with the fixes).  Every
entry must carry a non-empty justification.
"""
from __future__ import annotations

import json
from pathlib import Path

from repro.analysis.astlint import Finding


def load_allowlist(path: Path) -> dict[str, list[dict]]:
    if not path.exists():
        return {}
    text = path.read_text()
    if not text.strip():  # e.g. --allowlist /dev/null
        return {}
    data = json.loads(text)
    if not isinstance(data, dict):
        raise ValueError(f"{path}: allowlist must be a JSON object")
    for rule, entries in data.items():
        key = "module" if rule == "R7" else "file"
        for e in entries:
            if not isinstance(e, dict) or key not in e:
                raise ValueError(f"{path}: {rule} entry {e!r} missing {key!r}")
            if not str(e.get("justification", "")).strip():
                raise ValueError(f"{path}: {rule} entry {e[key]!r} lacks a justification")
    return data


def _entry_key(rule: str, entry: dict) -> tuple[str, str]:
    return rule, entry["module" if rule == "R7" else "file"]


def apply_allowlist(
    findings: list[Finding],
    allow: dict[str, list[dict]],
) -> tuple[list[Finding], list[tuple[str, str]]]:
    """→ (new findings not covered by the baseline, stale baseline keys)."""
    allowed = {_entry_key(rule, e) for rule, entries in allow.items() for e in entries}
    found = {(f.rule, f.key()) for f in findings}
    new = [f for f in findings if (f.rule, f.key()) not in allowed]
    stale = sorted(allowed - found)
    return new, stale


def render_allowlist(findings: list[Finding], previous: dict[str, list[dict]]) -> str:
    """Regenerate the baseline from current findings (``--update-allowlist``),
    carrying over justifications for entries that persist."""
    just = {_entry_key(r, e): e["justification"] for r, es in previous.items() for e in es}
    out: dict[str, list[dict]] = {}
    for f in sorted(findings):
        key = "module" if f.rule == "R7" else "file"
        justification = just.get((f.rule, f.key()), "TODO: justify or fix")
        entry = {key: f.key(), "justification": justification}
        if entry not in out.setdefault(f.rule, []):
            out[f.rule].append(entry)
    return json.dumps(out, indent=2) + "\n"
