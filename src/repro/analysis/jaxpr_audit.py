"""Layer 2 — jaxpr contract audit of the rule × backend × layer-kind matrix.

Traces every *valid* matrix cell abstractly (``jax.eval_shape`` for
state construction, ``jax.make_jaxpr`` for the step — nothing executes,
Pallas kernels abstract-eval without compiling) and checks the dataflow
contracts the paper's hardware makes statically:

* the cell traces clean on this toolchain,
* no float64 aval anywhere in the graph (x64 creep),
* no weak-typed top-level outputs (recompilation hazard: a weak output
  fed back as input retraces with a different aval),
* the timing state round-trips with identical dtypes (the uint8 history
  planes / int32 counters never silently promote), and
* cells whose datapath reads packed registers (history rules always;
  counter rules on kernel/sparse backends) actually carry uint8 operands
  in the graph.

Each cell also records a primitive-count table — a host-independent cost
fingerprint of the traced graph.  ``benchmarks/static_audit.py`` writes
it to the tracked ``BENCH_static.json``, which CI diffs against to catch
silent graph bloat the wall-clock benchmarks can't resolve.
"""
from __future__ import annotations

import collections
from typing import Any, Iterable

import jax
import jax.numpy as jnp

from repro import plasticity
from repro.core.engine import EngineConfig, engine_step, init_engine
from repro.kernels.dispatch import BACKENDS
from repro.models.snn import SNNConfig, SNNLayerSpec, init_snn, snn_step

KINDS = ("engine", "fc", "conv2d", "conv1d")

# tiny but layout-representative shapes: big enough to exercise the
# packing (n > 8 → multi-word registers) and conv patch extraction,
# small enough that 60+ abstract traces stay CI-cheap
_SPARSE_EVENTS = 4
_SNN_SHAPES = {
    "fc": ((16,), SNNLayerSpec("fc", out_features=8)),
    "conv2d": ((8, 8, 1), SNNLayerSpec("conv2d", out_features=4, kernel=3)),
    "conv1d": ((16, 2), SNNLayerSpec("conv1d", out_features=4, kernel=3, stride=2)),
}


def valid_cells(kinds: Iterable[str] = KINDS) -> list[tuple[str, str, str]]:
    """All (rule, backend, kind) combinations the shared validator accepts."""
    out = []
    for kind in kinds:
        for rule in plasticity.rule_names():
            for backend in BACKENDS:
                max_events = _SPARSE_EVENTS if backend == "sparse" else None
                try:
                    plasticity.validate_update_config(
                        rule=rule,
                        backend=backend,
                        pairing="nearest",
                        max_events=max_events,
                    )
                except ValueError:
                    continue
                out.append((rule, backend, kind))
    return out


def _abstract(tree):
    return jax.tree_util.tree_map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), tree)


def _cell_program(rule: str, backend: str, kind: str):
    """→ (state shapes, input ShapeDtypeStruct, traced step fn).

    The init functions are eager-only (they size buffers with Python
    ints), so the state is built concretely at the audit's tiny shapes
    and abstracted to ShapeDtypeStructs; only the *step* is traced.
    """
    key = jax.random.PRNGKey(0)
    max_events = _SPARSE_EVENTS if backend == "sparse" else None
    if kind == "engine":
        cfg = EngineConfig(n_pre=16, n_post=8, rule=rule, backend=backend, max_events=max_events)
        state = _abstract(init_engine(key, cfg))
        x = jax.ShapeDtypeStruct((cfg.n_pre,), jnp.bool_)
        return state, x, lambda s, sp: engine_step(s, sp, cfg)
    input_shape, spec = _SNN_SHAPES[kind]
    cfg = SNNConfig(
        name=f"audit-{kind}",
        input_shape=input_shape,
        layers=(spec,),
        rule=rule,
        backend=backend,
        max_events=max_events,
    )
    state = _abstract(init_snn(key, cfg, 1))
    x = jax.ShapeDtypeStruct((1, *input_shape), jnp.bool_)
    return state, x, lambda s, sp: snn_step(s, sp, cfg, train=True)


def _sub_jaxprs(value: Any):
    """Recursively yield jaxprs hiding in an eqn param value (pjit/cond/
    scan/pallas_call all stash them under different shapes)."""
    if isinstance(value, jax.core.ClosedJaxpr):
        yield value.jaxpr
    elif isinstance(value, jax.core.Jaxpr):
        yield value
    elif isinstance(value, (list, tuple)):
        for v in value:
            yield from _sub_jaxprs(v)
    elif isinstance(value, dict):
        for v in value.values():
            yield from _sub_jaxprs(v)


def _walk(jaxpr) -> Iterable:
    """All jaxprs reachable from ``jaxpr`` (itself included)."""
    seen = []
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        if any(j is s for s in seen):
            continue
        seen.append(j)
        yield j
        for eqn in j.eqns:
            for v in eqn.params.values():
                stack.extend(_sub_jaxprs(v))


def _avals(jaxpr) -> Iterable:
    for j in _walk(jaxpr):
        for var in list(j.invars) + list(j.constvars):
            yield var.aval
        for eqn in j.eqns:
            for var in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(var, "aval", None)
                if aval is not None:
                    yield aval


def _state_dtypes(tree) -> list[str]:
    return [str(leaf.dtype) for leaf in jax.tree_util.tree_leaves(tree)]


def audit_cell(rule: str, backend: str, kind: str) -> dict:
    """Trace one matrix cell and check its contracts; never raises."""
    cell: dict[str, Any] = {"rule": rule, "backend": backend, "kind": kind, "violations": []}
    try:
        state, x, fn = _cell_program(rule, backend, kind)
        closed = jax.make_jaxpr(fn)(state, x)
        out_shapes = jax.eval_shape(fn, state, x)
    except Exception as e:  # noqa: BLE001 — any trace failure is the finding
        cell["violations"].append(f"trace failed: {type(e).__name__}: {e}")
        return cell

    avals = list(_avals(closed.jaxpr))
    dtypes = {str(getattr(a, "dtype", "")) for a in avals}
    eqns = [eqn for j in _walk(closed.jaxpr) for eqn in j.eqns]
    counts = collections.Counter(e.primitive.name for e in eqns)

    cell["n_eqns"] = sum(counts.values())
    cell["primitives"] = dict(sorted(counts.items()))
    cell["has_uint8"] = "uint8" in dtypes
    cell["has_f64"] = "float64" in dtypes
    weak = [str(a) for a in closed.out_avals if getattr(a, "weak_type", False)]
    cell["weak_outputs"] = weak

    in_dt, out_dt = _state_dtypes(state), _state_dtypes(out_shapes[0])
    cell["state_dtypes_preserved"] = in_dt == out_dt

    # packed-register cells must really carry uint8: the history rules
    # keep uint8 bitplanes in their state on every backend; the counter
    # rules expose a uint8 readout word only on the kernel datapaths
    rule_obj = plasticity.get_rule(rule)
    uint8_expected = rule_obj.has_sparse or backend != "reference"
    cell["uint8_expected"] = uint8_expected

    if cell["has_f64"]:
        cell["violations"].append("float64 aval in traced graph")
    if weak:
        cell["violations"].append(f"weak-typed outputs: {weak}")
    if not cell["state_dtypes_preserved"]:
        cell["violations"].append(f"state dtypes changed across the step: {in_dt} → {out_dt}")
    if uint8_expected and not cell["has_uint8"]:
        cell["violations"].append("no uint8 operand in a packed-register cell")
    return cell


def run_audit(kinds: Iterable[str] = KINDS) -> dict:
    cells = [audit_cell(rule, backend, kind) for rule, backend, kind in valid_cells(kinds)]
    return {
        "jax_version": jax.__version__,
        "kinds": list(kinds),
        "n_cells": len(cells),
        "n_violating": sum(1 for c in cells if c["violations"]),
        "cells": cells,
    }
