from repro.train.optimizer import (OptimizerConfig, OptState, adamw_update,
                                   init_opt_state, lr_schedule)
from repro.train.train_step import (TrainConfig, init_training, lm_loss,
                                    make_train_step, batch_shardings)
from repro.train.stdp_trainer import (TrainerConfig, assign_labels,
                                      assignment_accuracy, assignment_predict,
                                      evaluate, train_to_accuracy)
