from repro.train.optimizer import (OptimizerConfig, OptState, adamw_update,
                                   init_opt_state, lr_schedule)
from repro.train.train_step import (TrainConfig, init_training, lm_loss,
                                    make_train_step, batch_shardings)
