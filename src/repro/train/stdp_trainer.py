"""Unsupervised STDP training-to-accuracy loop (the paper's system-level
protocol behind Table II).

The pipeline is the classic unsupervised-STDP classifier recipe (Diehl &
Cook style, cf. the paired-competition analysis of Goupy et al. in
PAPERS.md), wired through this repo's rule-owned dispatch so every cell of
the rule × backend matrix trains end-to-end:

  1. **Feature learning** — epochs of rate-coded batches streamed from
     ``repro.data.pipeline.spike_stream`` (double-buffered via
     ``Prefetcher``) drive ``snn.run_snn(train=True)``; the excitatory
     layer competes through soft lateral inhibition / hard WTA and
     adaptive-threshold homeostasis (``SNNConfig.hard_wta`` /
     ``theta_plus`` / ``theta_tau``), which is what turns local STDP into
     class-selective receptive fields.
  2. **Label assignment** — a held-out pass (``train=False``, θ and
     weights frozen) records per-neuron spike counts; each excitatory
     neuron is assigned to the class it responds to most
     (:func:`assign_labels`).
  3. **Evaluation** — a second held-out pass classifies each sample by
     the assigned-population vote (:func:`assignment_predict`): argmax
     over classes of the mean spike count of the neurons assigned to that
     class.

No gradients, no labels in the weight path — the only supervised step is
naming the neurons.  :func:`train_to_accuracy` runs the loop and returns
the per-epoch accuracy curve; ``benchmarks/accuracy.py`` uses it to pin
the paper's claim that ITP-STDP matches exact STDP *accuracy*, not just
trajectories.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp

from repro.data.pipeline import Prefetcher, encode_batch, spike_stream
from repro.models import snn

Sampler = Callable[[jax.Array, int], tuple[jax.Array, jax.Array]]


@dataclasses.dataclass(frozen=True)
class TrainerConfig:
    """Epoch-level knobs of the train-to-accuracy loop.

    One epoch = ``batches_per_epoch`` rasters of ``batch`` samples ×
    ``t_steps`` simulation steps, followed by an assignment pass
    (``assign_batches``) and an evaluation pass (``eval_batches``) on
    freshly drawn held-out samples.  All batches share one size so the
    jitted ``run_snn`` compiles exactly twice (train / eval variant).
    """

    epochs: int = 5
    batches_per_epoch: int = 8
    batch: int = 16
    t_steps: int = 30
    assign_batches: int = 6
    eval_batches: int = 4
    seed: int = 0
    prefetch: bool = True

    def __post_init__(self):
        for name in (
            "epochs",
            "batches_per_epoch",
            "batch",
            "t_steps",
            "assign_batches",
            "eval_batches",
        ):
            v = getattr(self, name)
            if v < 1:
                raise ValueError(f"{name} must be >= 1, got {v}")


# ---------------------------------------------------------------------------
# Label-assignment evaluator
# ---------------------------------------------------------------------------


def assign_labels(counts: jax.Array, labels: jax.Array, n_classes: int) -> jax.Array:
    """Assign each feature neuron to its max-mean-response class.

    ``counts`` is ``(N, F)`` spike counts over a held-out pass, ``labels``
    ``(N,)`` int; returns ``(F,)`` int32 assignments.  Neurons that never
    fire fall to class 0 (they carry no vote weight either way).
    """
    onehot = jax.nn.one_hot(labels, n_classes, dtype=jnp.float32)  # (N, C)
    per_class = onehot.T @ jnp.asarray(counts, jnp.float32)  # (C, F)
    per_class = per_class / jnp.maximum(onehot.sum(axis=0)[:, None], 1.0)
    return jnp.argmax(per_class, axis=0).astype(jnp.int32)


def assignment_predict(
    counts: jax.Array,
    assignments: jax.Array,
    n_classes: int,
) -> jax.Array:
    """Classify by assigned-population vote.

    Per sample, each class scores the *mean* spike count of the neurons
    assigned to it (mean, not sum, so a class owning many neurons gets no
    free advantage); returns ``(N,)`` int32 predictions.
    """
    onehot = jax.nn.one_hot(assignments, n_classes, dtype=jnp.float32)  # (F, C)
    pop = jnp.maximum(onehot.sum(axis=0), 1.0)  # (C,)
    votes = jnp.asarray(counts, jnp.float32) @ onehot / pop  # (N, C)
    return jnp.argmax(votes, axis=-1).astype(jnp.int32)


def assignment_accuracy(
    counts: jax.Array,
    labels: jax.Array,
    assignments: jax.Array,
    n_classes: int,
) -> float:
    pred = assignment_predict(counts, assignments, n_classes)
    return float(jnp.mean(pred == labels))


# ---------------------------------------------------------------------------
# Held-out feature collection + evaluation
# ---------------------------------------------------------------------------


def _collect_counts(
    state: snn.SNNState,
    cfg: snn.SNNConfig,
    sampler: Sampler,
    key: jax.Array,
    *,
    n_batches: int,
    batch: int,
    t_steps: int,
) -> tuple[jax.Array, jax.Array]:
    """Frozen-network spike counts over ``n_batches`` held-out batches."""
    feats, labels = [], []
    st = state
    for _ in range(n_batches):
        key, k_data, k_enc = jax.random.split(key, 3)
        x, y = sampler(k_data, batch)
        spikes = encode_batch(k_enc, x, t_steps)
        st = snn.reset_dynamics(st, cfg, batch)
        st, counts = snn.run_snn(st, spikes, cfg, train=False)
        feats.append(counts)
        labels.append(y)
    return jnp.concatenate(feats), jnp.concatenate(labels)


def evaluate(
    state: snn.SNNState,
    cfg: snn.SNNConfig,
    sampler: Sampler,
    n_classes: int,
    tcfg: TrainerConfig,
    key: jax.Array,
) -> dict:
    """Label-assignment evaluation of a trained network.

    Assignment and evaluation use disjoint key folds, so the reported
    accuracy is a true held-out number for the assignment too.
    """
    k_assign, k_eval = jax.random.split(key)
    counts_a, labels_a = _collect_counts(
        state,
        cfg,
        sampler,
        k_assign,
        n_batches=tcfg.assign_batches,
        batch=tcfg.batch,
        t_steps=tcfg.t_steps,
    )
    assignments = assign_labels(counts_a, labels_a, n_classes)
    counts_e, labels_e = _collect_counts(
        state,
        cfg,
        sampler,
        k_eval,
        n_batches=tcfg.eval_batches,
        batch=tcfg.batch,
        t_steps=tcfg.t_steps,
    )
    acc = assignment_accuracy(counts_e, labels_e, assignments, n_classes)
    return {
        "accuracy": acc,
        "assignments": assignments,
        "n_assigned_classes": int(jnp.unique(assignments).shape[0]),
        "mean_eval_rate": float(counts_e.mean()) / tcfg.t_steps,
    }


# ---------------------------------------------------------------------------
# Epoch-level training loop
# ---------------------------------------------------------------------------


def train_to_accuracy(
    cfg: snn.SNNConfig,
    sampler: Sampler,
    n_classes: int,
    tcfg: TrainerConfig,
    *,
    verbose: bool = False,
) -> dict:
    """Unsupervised STDP epochs + per-epoch label-assignment accuracy.

    Streams ``spike_stream`` batches (prefetched when ``tcfg.prefetch``)
    through ``run_snn(train=True)`` with dynamics reset between rasters,
    then evaluates after every epoch.  Works for every valid rule ×
    backend cell of the matrix — the loop only touches the config-level
    dispatch.  Returns the result dict (accuracy curve + final state
    diagnostics); the trained state rides along under ``"state"``.
    """
    key = jax.random.PRNGKey(tcfg.seed)
    state = snn.init_snn(key, cfg, tcfg.batch)
    curve, rates = [], []
    train_seconds = 0.0
    for epoch in range(tcfg.epochs):
        k_epoch = jax.random.fold_in(key, 1000 + epoch)
        stream = spike_stream(
            k_epoch,
            sampler,
            batch=tcfg.batch,
            t_steps=tcfg.t_steps,
            n_steps=tcfg.batches_per_epoch,
        )
        if tcfg.prefetch:
            stream = Prefetcher(stream)
        t0 = time.time()
        try:
            for b in stream:
                state, _ = snn.run_snn(state, b["spikes"], cfg, train=True)
                state = snn.reset_dynamics(state, cfg, tcfg.batch)
            jax.block_until_ready(state.weights)
        finally:
            if isinstance(stream, Prefetcher):
                stream.close()
        train_seconds += time.time() - t0
        k_eval = jax.random.fold_in(key, 2000 + epoch)
        ev = evaluate(state, cfg, sampler, n_classes, tcfg, k_eval)
        curve.append(ev["accuracy"])
        rates.append(ev["mean_eval_rate"])
        if verbose:
            print(
                f"  epoch {epoch + 1:2d}/{tcfg.epochs}: "
                f"accuracy {ev['accuracy']:.3f} "
                f"(rate {ev['mean_eval_rate']:.3f}, "
                f"{ev['n_assigned_classes']}/{n_classes} classes assigned)",
                flush=True,
            )
    sim_steps = tcfg.epochs * tcfg.batches_per_epoch * tcfg.t_steps
    return {
        "net": cfg.name,
        "rule": cfg.rule,
        "backend": cfg.backend,
        "epochs": tcfg.epochs,
        "batch": tcfg.batch,
        "t_steps": tcfg.t_steps,
        "sim_steps": sim_steps,
        "chance": 1.0 / n_classes,
        "accuracy_curve": [float(a) for a in curve],
        "final_accuracy": float(curve[-1]),
        "mean_eval_rates": [float(r) for r in rates],
        "train_seconds": round(train_seconds, 3),
        "state": state,
    }
