"""LM training step: loss, gradients, optimizer — pjit/GSPMD-distributed.

The step is a pure function ``(params, opt_state, batch) → (params',
opt_state', metrics)``; ``make_train_step`` closes over the model/optimizer
configs and (optionally) a mesh, returning the jitted step together with
the in/out shardings the launcher and the dry-run both use.

Cross-pod handling (multi-pod mesh): gradients are computed from the
pod-local batch shard inside a ``shard_map`` manual only over ``pod``,
then exchanged with the po2-compressed all-gather
(``distributed.compression``) — the paper's sign·2^e format on the slow
inter-pod links.  ``pod_compression=False`` falls back to a plain f32
``pmean`` (the ablation baseline); single-pod meshes skip the block
entirely and GSPMD reduces over ``data`` as usual.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed import compression
from repro.distributed.sharding import (batch_axes, param_shardings,
                                        shard_map_compat, use_mesh)
from repro.models import transformer
from repro.train.optimizer import (OptimizerConfig, OptState, adamw_update,
                                   init_opt_state)

Params = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    remat: str = "full"              # none | full | dots
    z_loss: float = 1e-4
    pod_compression: bool = True     # po2 wire format across the pod axis
    unroll: bool = False             # unroll layer scans (measurement only)
    sharding_profile: str = "fsdp"   # fsdp | replicated (weights over data)


# ---------------------------------------------------------------------------
# Loss
# ---------------------------------------------------------------------------

def lm_loss(params: Params, cfg, batch: dict, *, train_cfg: TrainConfig,
            vis_embed: jax.Array | None = None) -> tuple[jax.Array, dict]:
    """Next-token cross entropy (+ z-loss, + MoE aux) over a token batch.

    ``batch['labels'] == -1`` marks ignored positions.  Softmax statistics
    accumulate in f32 while logits stay in the compute dtype, which keeps
    the (B, S, V) intermediate at bf16 — the difference between fitting
    and OOM at vocab 152k.
    """
    kw = {}
    if cfg.family == "vlm":
        kw["vis_embed"] = vis_embed if vis_embed is not None \
            else batch.get("vis_embed")
    if "embeds" in batch:
        logits, aux = transformer.forward(params, cfg, embeds=batch["embeds"],
                                          remat=train_cfg.remat,
                                          unroll=train_cfg.unroll, **kw)
    else:
        logits, aux = transformer.forward(params, cfg, tokens=batch["tokens"],
                                          remat=train_cfg.remat,
                                          unroll=train_cfg.unroll, **kw)
    labels = batch["labels"]
    mask = (labels >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels, 0)
    lse = jax.scipy.special.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = lse - gold.astype(jnp.float32)
    n_tok = jnp.maximum(jnp.sum(mask), 1.0)
    ce = jnp.sum(nll * mask) / n_tok
    zl = train_cfg.z_loss * jnp.sum((lse ** 2) * mask) / n_tok
    loss = ce + zl + aux.get("moe_aux", 0.0) + aux.get("moe_z", 0.0)
    metrics = {"loss": loss, "ce": ce, "z_loss": zl,
               "moe_aux": aux.get("moe_aux", jnp.zeros(())),
               "tokens": n_tok}
    return loss, metrics


# ---------------------------------------------------------------------------
# Step factory
# ---------------------------------------------------------------------------

def batch_shardings(mesh: Mesh, batch_tree: dict) -> dict:
    """Batch arrays shard their leading dim over ('pod','data')."""
    ax = batch_axes(mesh)
    def one(x):
        return NamedSharding(mesh, P(ax, *([None] * (x.ndim - 1))))
    return jax.tree_util.tree_map(one, batch_tree)


def make_train_step(cfg, opt_cfg: OptimizerConfig,
                    train_cfg: TrainConfig = TrainConfig(),
                    mesh: Mesh | None = None
                    ) -> Callable[[Params, OptState, dict], tuple]:
    """Build the (optionally distributed) train step.

    Without a mesh: plain jit for CPU tests.  With a mesh: the caller is
    expected to run under ``use_mesh(mesh)`` / pass sharded inputs; the
    returned function is jit-compiled with GSPMD handling data/model axes
    and the explicit pod block handling the slow axis.
    """
    multi_pod = mesh is not None and "pod" in mesh.axis_names

    def grads_and_metrics(params, batch):
        return jax.value_and_grad(
            lambda p: lm_loss(p, cfg, batch, train_cfg=train_cfg),
            has_aux=True)(params)

    def step(params: Params, opt_state: OptState, batch: dict):
        if multi_pod:
            # pod-local grads (GSPMD shards data/model inside the manual-
            # over-pod region), then the explicit compressed exchange; the
            # post-mean grads/metrics are genuinely pod-replicated, so
            # out_specs=P() is truthful
            def local(p, b):
                (loss, metrics), grads = grads_and_metrics(p, b)
                grads = compression.pod_mean_tree(
                    grads, compress=train_cfg.pod_compression)
                metrics = jax.tree_util.tree_map(
                    lambda x: jax.lax.pmean(x, "pod"), metrics)
                return grads, metrics

            # partial-manual over 'pod' only, through the single version
            # shim (jax.shard_map on new toolchains, experimental
            # shard_map with auto=complement on jax 0.4.x — this jax has
            # no jax.shard_map at all, lint rule R1 keeps it that way)
            grads, metrics = shard_map_compat(
                local, mesh=mesh,
                in_specs=(P(), P("pod")), out_specs=P(),
                axis_names={"pod"})(params, batch)
        else:
            (loss, metrics), grads = grads_and_metrics(params, batch)
        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, params, grads, opt_state)
        metrics = dict(metrics, **opt_metrics)
        return new_params, new_opt, metrics

    return step


def init_training(key: jax.Array, cfg, opt_cfg: OptimizerConfig,
                  mesh: Mesh | None = None):
    """Initialise (params, opt_state); sharded when a mesh is given."""
    if mesh is None:
        params = transformer.init_model(key, cfg)
        return params, init_opt_state(params)
    with use_mesh(mesh):
        shape_tree = jax.eval_shape(lambda k: transformer.init_model(k, cfg),
                                    key)
        shardings = param_shardings(cfg, shape_tree, mesh)
        params = jax.jit(lambda k: transformer.init_model(k, cfg),
                         out_shardings=shardings)(key)
        opt_shardings = OptState(
            step=NamedSharding(mesh, P()), mu=shardings, nu=shardings)
        opt_state = jax.jit(init_opt_state,
                            out_shardings=opt_shardings)(params)
    return params, opt_state
