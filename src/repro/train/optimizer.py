"""Pure-JAX optimizers: AdamW and the beyond-paper "ITP-AdamW" variant.

ITP-AdamW snaps the per-parameter update to the nearest power of two
(sign·2^round(log2|u|)) — the ITP-STDP quantiser applied to gradient
descent.  On hardware this makes the weight-update datapath shift-add only
(the paper's §III argument); at cluster scale it composes with the po2
gradient compression in ``repro.distributed.compression``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.po2_quant.ref import po2_roundtrip_ref


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    po2_update: bool = False       # ITP-AdamW: po2-quantised updates


class OptState(NamedTuple):
    step: jax.Array
    mu: Any        # first moment  (pytree like params)
    nu: Any        # second moment


def init_opt_state(params) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree_util.tree_map(jnp.copy, zeros))


def lr_schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    """Linear warmup → cosine decay to min_lr_ratio·lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 \
        * (1 + jnp.cos(jnp.pi * frac))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jax.Array:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in leaves))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree_util.tree_map(lambda g: g * scale, tree), norm


def adamw_update(cfg: OptimizerConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    grads = jax.tree_util.tree_map(lambda g: g.astype(jnp.float32), grads)
    if cfg.grad_clip > 0:
        grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    else:
        gnorm = global_norm(grads)
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        u = mhat / (jnp.sqrt(vhat) + cfg.eps)
        u = u + cfg.weight_decay * p.astype(jnp.float32)
        if cfg.po2_update:
            u = po2_roundtrip_ref(u)       # ITP quantiser: sign·2^round(log2|u|)
        p_new = p.astype(jnp.float32) - lr * u
        return p_new.astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, OptState(step=step, mu=new_m, nu=new_v), metrics
