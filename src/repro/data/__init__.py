from repro.data.synthetic import (LMBatchSpec, host_shard, lm_batches,
                                  synthetic_digits, synthetic_fashion,
                                  synthetic_fault, zipf_tokens)
from repro.data.pipeline import Prefetcher, encode_batch, spike_stream

__all__ = [
    "LMBatchSpec", "host_shard", "lm_batches", "synthetic_digits",
    "synthetic_fashion", "synthetic_fault", "zipf_tokens",
    "Prefetcher", "encode_batch", "spike_stream",
]
