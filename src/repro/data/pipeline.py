"""Spike-encoding data pipeline (paper §IV-B front half).

Chains the synthetic generators with min-max normalisation (eq. 28) and
Bernoulli rate coding (eq. 29) into (T, B, N) spike rasters ready for the
SNN training loop, plus a double-buffered prefetcher so host-side encoding
overlaps device compute.
"""
from __future__ import annotations

import collections
import threading
from typing import Callable, Iterator

import jax

from repro.core.encoding import minmax_normalise, rate_code


def encode_batch(key: jax.Array, x: jax.Array, t_steps: int) -> jax.Array:
    """(B, ...) floats → (T, B, features) {0,1} spikes.

    Per-sample min-max normalisation (eq. 28) then Bernoulli rate coding
    (eq. 29); feature dims are flattened.
    """
    B = x.shape[0]
    flat = x.reshape(B, -1)
    norm = minmax_normalise(flat, axis=-1)
    return rate_code(key, norm, t_steps)               # (T, B, N)


def spike_stream(key: jax.Array,
                 sampler: Callable[[jax.Array, int], tuple[jax.Array, jax.Array]],
                 *, batch: int, t_steps: int,
                 n_steps: int | None = None) -> Iterator[dict]:
    """Stream of {spikes (T,B,N), labels (B,)} batches from a sampler."""
    step = 0
    while n_steps is None or step < n_steps:
        key, k_data, k_enc = jax.random.split(key, 3)
        x, labels = sampler(k_data, batch)
        yield {"spikes": encode_batch(k_enc, x, t_steps), "labels": labels}
        step += 1


class Prefetcher:
    """Double-buffered background prefetch of an iterator (host → device).

    The training loop's `next()` overlaps the *next* batch's generation +
    encoding with the current step's device compute — the standard input-
    pipeline trick, testable on CPU.
    """

    def __init__(self, it: Iterator, depth: int = 2):
        self._it = it
        self._q: collections.deque = collections.deque()
        self._depth = depth
        self._lock = threading.Lock()
        self._done = False
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._event = threading.Event()
        self._space = threading.Event()
        self._space.set()
        self._thread.start()

    def _fill(self):
        try:
            for item in self._it:
                if self._stop.is_set():
                    return
                while not self._stop.is_set():
                    with self._lock:
                        if len(self._q) < self._depth:
                            self._q.append(jax.device_put(item))
                            self._event.set()
                            break
                    self._space.clear()
                    self._space.wait(timeout=0.1)
        finally:
            self._done = True
            self._event.set()

    def close(self, timeout: float = 5.0) -> None:
        """Stop the background thread and drop buffered batches.

        Safe to call at any point — including before the source iterator is
        exhausted (early abandonment: a training loop that stops at an
        accuracy target, or an exception unwinding through the consumer).
        Idempotent; after it returns the fill thread has exited.
        """
        self._stop.set()
        self._space.set()          # unblock a producer waiting for space
        self._thread.join(timeout=timeout)
        with self._lock:
            self._q.clear()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def __iter__(self):
        return self

    def __next__(self):
        while True:
            with self._lock:
                if self._q:
                    item = self._q.popleft()
                    self._space.set()
                    return item
                if self._done:
                    raise StopIteration
            self._event.clear()
            self._event.wait(timeout=0.1)
