"""Procedurally generated datasets standing in for the paper's three
datasets (MNIST, Fashion-MNIST, motor rotor-fault) plus LM token streams.

MNIST/F-MNIST/the fault dataset are not available offline (DESIGN.md §8);
these generators produce class-structured data with the same shapes and
dynamic range, so the *parity* experiments of Table II (exact STDP vs
ITP-STDP ± compensation under one protocol) remain meaningful.

All generators are pure functions of a PRNG key — reproducible, and
`vmap`-/`scan`-friendly for streaming pipelines.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Image-like datasets (digits / fashion stand-ins)
# ---------------------------------------------------------------------------

def _digit_prototypes(side: int, n_classes: int) -> jax.Array:
    """Deterministic stroke-pattern prototypes, one per class (c, side, side)."""
    yy, xx = jnp.meshgrid(jnp.linspace(-1, 1, side), jnp.linspace(-1, 1, side),
                          indexing="ij")
    protos = []
    for c in range(n_classes):
        ang = 2.0 * jnp.pi * c / n_classes
        # oriented bar + class-dependent ring: distinct, overlapping strokes;
        # MNIST-like contrast (strokes saturate near 1, background at 0 —
        # this also matches the short-ISI regime behind the paper's Fig. 6)
        bar = jnp.exp(-((xx * jnp.cos(ang) + yy * jnp.sin(ang)) ** 2) / 0.05)
        r = jnp.sqrt(xx ** 2 + yy ** 2)
        ring = jnp.exp(-((r - 0.3 - 0.4 * (c % 3) / 2.0) ** 2) / 0.02)
        protos.append(jnp.clip(1.8 * (0.7 * bar + 0.5 * ring), 0.0, 1.0))
    return jnp.stack(protos)


def synthetic_digits(key: jax.Array, n: int, *, side: int = 28,
                     n_classes: int = 10, noise: float = 0.08,
                     jitter: int = 2) -> tuple[jax.Array, jax.Array]:
    """MNIST stand-in: (n, side, side) float in [0,1], labels (n,) int32."""
    k_lbl, k_shift, k_noise = jax.random.split(key, 3)
    labels = jax.random.randint(k_lbl, (n,), 0, n_classes)
    protos = _digit_prototypes(side, n_classes)
    imgs = protos[labels]                                       # (n, s, s)
    # per-sample translation jitter
    shifts = jax.random.randint(k_shift, (n, 2), -jitter, jitter + 1)
    imgs = jax.vmap(lambda im, sh: jnp.roll(im, sh, axis=(0, 1)))(imgs, shifts)
    imgs = imgs + noise * jax.random.normal(k_noise, imgs.shape)
    imgs = jnp.clip(imgs, 0.0, 1.0)
    # sensor floor: true-zero background, as in MNIST (anti-aliased strokes
    # on exact-zero canvas) — matters for the ISI statistics of §IV-B
    return jnp.where(imgs < 0.12, 0.0, imgs), labels


def synthetic_fashion(key: jax.Array, n: int, *, side: int = 28,
                      n_classes: int = 10, noise: float = 0.2
                      ) -> tuple[jax.Array, jax.Array]:
    """Fashion-MNIST stand-in: textured silhouettes (higher-noise regime)."""
    k_lbl, k_tex, k_noise = jax.random.split(key, 3)
    labels = jax.random.randint(k_lbl, (n,), 0, n_classes)
    yy, xx = jnp.meshgrid(jnp.linspace(-1, 1, side), jnp.linspace(-1, 1, side),
                          indexing="ij")
    freqs = 2.0 + jnp.arange(n_classes, dtype=jnp.float32)      # per-class texture
    widths = 0.35 + 0.4 * (jnp.arange(n_classes) % 4) / 3.0
    f, w = freqs[labels], widths[labels]
    sil = (jnp.abs(xx)[None] < w[:, None, None]).astype(jnp.float32) \
        * (jnp.abs(yy)[None] < 0.8).astype(jnp.float32)
    tex = 0.7 + 0.3 * jnp.sin(f[:, None, None] * jnp.pi
                              * (xx[None] + yy[None])
                              + jax.random.uniform(k_tex, (n, 1, 1)) * jnp.pi)
    imgs = sil * tex + noise * jax.random.normal(k_noise, sil.shape) * sil
    imgs = jnp.clip(imgs, 0.0, 1.0)
    return jnp.where(imgs < 0.12, 0.0, imgs), labels


def synthetic_fault(key: jax.Array, n: int, *, length: int = 512,
                    channels: int = 2, n_classes: int = 4,
                    noise: float = 0.1) -> tuple[jax.Array, jax.Array]:
    """Motor fault stand-in: (n, length, channels) current/flux signals.

    Class structure follows the physics of rotor faults: a fundamental at
    f0 plus class-dependent sideband pairs (broken bar ≈ ±2sf0 sidebands,
    eccentricity ≈ rotational-frequency modulation, bearing ≈ impulsive
    bursts), healthy = fundamental only.
    """
    k_lbl, k_ph, k_noise, k_imp = jax.random.split(key, 4)
    labels = jax.random.randint(k_lbl, (n,), 0, n_classes)
    t = jnp.linspace(0.0, 1.0, length)
    f0 = 50.0
    phase = jax.random.uniform(k_ph, (n, 1, 1)) * 2 * jnp.pi
    tt = t[None, :, None]
    ch_shift = jnp.arange(channels)[None, None, :] * (jnp.pi / 2)  # flux lags current
    base = jnp.sin(2 * jnp.pi * f0 * tt + phase + ch_shift)

    lbl = labels[:, None, None]
    side = 0.4 * jnp.sin(2 * jnp.pi * (f0 - 4.0) * tt + phase + ch_shift) \
         + 0.4 * jnp.sin(2 * jnp.pi * (f0 + 4.0) * tt + phase + ch_shift)
    ecc = 0.5 * jnp.sin(2 * jnp.pi * 12.5 * tt + ch_shift) * base
    impulses = (jax.random.uniform(k_imp, (n, length, 1)) > 0.98) \
        .astype(jnp.float32) * 1.5
    sig = base \
        + jnp.where(lbl == 1, side, 0.0) \
        + jnp.where(lbl == 2, ecc, 0.0) \
        + jnp.where(lbl == 3, impulses, 0.0)
    sig = sig + noise * jax.random.normal(k_noise, sig.shape)
    return sig, labels


# ---------------------------------------------------------------------------
# LM token streams
# ---------------------------------------------------------------------------

def zipf_tokens(key: jax.Array, batch: int, seq: int, vocab: int,
                alpha: float = 1.1) -> jax.Array:
    """Zipf-distributed token ids (B, S) — realistic LM token marginals."""
    ranks = jnp.arange(1, vocab + 1, dtype=jnp.float32)
    logp = -alpha * jnp.log(ranks)
    return jax.random.categorical(key, logp[None, None, :],
                                  shape=(batch, seq)).astype(jnp.int32)


@dataclasses.dataclass(frozen=True)
class LMBatchSpec:
    batch: int
    seq: int
    vocab: int


def lm_batches(key: jax.Array, spec: LMBatchSpec,
               n_steps: int | None = None) -> Iterator[dict]:
    """Infinite (or n_steps-long) stream of {tokens, labels} LM batches.

    labels = tokens shifted left (next-token prediction); the final column
    is masked with -1 (ignored by the loss).
    """
    step = 0
    while n_steps is None or step < n_steps:
        key, sub = jax.random.split(key)
        toks = zipf_tokens(sub, spec.batch, spec.seq, spec.vocab)
        labels = jnp.concatenate(
            [toks[:, 1:], jnp.full((spec.batch, 1), -1, jnp.int32)], axis=1)
        yield {"tokens": toks, "labels": labels}
        step += 1


def host_shard(batch: dict, host_id: int, n_hosts: int) -> dict:
    """Per-host slice of a global batch (multi-host data loading)."""
    def slc(x):
        per = x.shape[0] // n_hosts
        return x[host_id * per:(host_id + 1) * per]
    return {k: slc(v) for k, v in batch.items()}
