"""qwen2-moe-a2.7b — [moe] 24L d2048 16H (kv=16) expert d_ff 1408
vocab 151936, 60 routed experts top-4 + 4 shared (5632 fused width).
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab_size=151936,
    qkv_bias=True,
    n_experts=60,
    n_experts_per_tok=4,
    n_experts_padded=64,     # EP divisibility on model=16 (padding never routed)
    moe_d_ff=1408,
    shared_d_ff=5632,        # 4 shared experts fused
    norm_topk=False,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-moe-a2.7b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=48,
    vocab_size=256,
    qkv_bias=True,
    n_experts=8,
    n_experts_per_tok=2,
    moe_d_ff=48,
    shared_d_ff=96,
    norm_topk=False,
)
