"""musicgen-medium — [audio] 48L d1536 24H (kv=24, MHA) d_ff 6144
vocab 2048; decoder-only over EnCodec tokens, sinusoidal positions,
LayerNorm + GELU MLP.  [arXiv:2306.05284; hf]

The EnCodec frontend is a stub per the brief: ``input_specs()`` provides
precomputed frame embeddings (B, S, d_model); training targets are the
next-step codebook tokens (vocab 2048).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    pos_embedding="sinusoidal",
    norm="layernorm",
    mlp="gelu",
)

SMOKE = ModelConfig(
    name="musicgen-medium-smoke",
    family="audio",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab_size=128,
    pos_embedding="sinusoidal",
    norm="layernorm",
    mlp="gelu",
)
