"""mamba2-1.3b — [ssm] 48L d2048 attn-free, vocab 50280, ssm_state=128,
SSD (state-space duality), tied embeddings.  [arXiv:2405.21060; unverified]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    tie_embeddings=True,
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,            # d_inner 4096 → 64 heads
    ssm_groups=1,
    d_conv=4,
    ssd_chunk=256,
)

SMOKE = ModelConfig(
    name="mamba2-1.3b-smoke",
    family="ssm",
    n_layers=3,
    d_model=64,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab_size=256,
    tie_embeddings=True,
    ssm_state=16,
    ssm_head_dim=16,         # d_inner 128 → 8 heads
    ssm_expand=2,
    d_conv=4,
    ssd_chunk=8,
)
