"""yi-9b — [dense] 48L d4096 32H (GQA kv=4) d_ff 11008 vocab 64000,
llama-arch GQA.  [arXiv:2403.04652; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="yi-9b",
    family="dense",
    n_layers=48,
    d_model=4096,
    n_heads=32,
    n_kv_heads=4,
    d_ff=11008,
    vocab_size=64000,
    rope_theta=5_000_000.0,
)

SMOKE = ModelConfig(
    name="yi-9b-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=8,
    n_kv_heads=2,
    d_ff=160,
    vocab_size=512,
    rope_theta=5_000_000.0,
)
