"""phi3.5-moe-42b-a6.6b — [moe] 32L d4096 32H (GQA kv=8) expert d_ff 6400
vocab 32064, 16 experts top-2 (Mixtral-style, LayerNorm, attn bias).
[hf:microsoft/Phi-3.5-MoE-instruct; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=6400,
    vocab_size=32064,
    qkv_bias=True,
    norm="layernorm",
    n_experts=16,
    n_experts_per_tok=2,
    moe_d_ff=6400,
    norm_topk=True,
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="phi3.5-moe-42b-a6.6b-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=96,
    vocab_size=256,
    qkv_bias=True,
    norm="layernorm",
    n_experts=4,
    n_experts_per_tok=2,
    moe_d_ff=96,
    norm_topk=True,
)
