"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``.

Ten assigned LM architectures + the paper's own SNN networks (registered in
``repro.models.snn``; SNNs are not part of the LM dry-run grid).
"""
from __future__ import annotations

import importlib

from repro.configs.shapes import SHAPES, ShapeSpec, shapes_for  # noqa: F401

_MODULES = {
    "llama-3.2-vision-11b": "repro.configs.llama32_vision_11b",
    "qwen1.5-32b": "repro.configs.qwen15_32b",
    "yi-9b": "repro.configs.yi_9b",
    "qwen3-0.6b": "repro.configs.qwen3_0_6b",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "qwen2-moe-a2.7b": "repro.configs.qwen2_moe_a2_7b",
    "phi3.5-moe-42b-a6.6b": "repro.configs.phi35_moe_42b",
    "mamba2-1.3b": "repro.configs.mamba2_1_3b",
    "musicgen-medium": "repro.configs.musicgen_medium",
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {list(_MODULES)}")
    return importlib.import_module(_MODULES[name]).CONFIG


def get_smoke_config(name: str):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; have {list(_MODULES)}")
    return importlib.import_module(_MODULES[name]).SMOKE


def all_configs():
    return {n: get_config(n) for n in ARCH_NAMES}
