"""llama-3.2-vision-11b — [vlm] 40L d4096 32H (GQA kv=8) d_ff 14336
vocab 128256, cross-attn image layers every 5th layer.
[hf:meta-llama/Llama-3.2-11B-Vision; unverified]

The vision frontend is a stub per the brief: ``input_specs()`` supplies
precomputed patch embeddings (B, 1601, 7680); the backbone projects them to
K/V inside each gated cross-attention layer (q/k-norm + tanh gate, as in
the HF reference).  Structurally we group layers into 8 periods of
(4 self + 1 cross), matching HF's cross layers {3,8,…,38} in count and
spacing.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    n_layers=40,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500_000.0,
    cross_attn_layers=(3, 8, 13, 18, 23, 28, 33, 38),
    n_vis_tokens=1601,
    vis_dim=7680,
)

SMOKE = ModelConfig(
    name="llama-3.2-vision-11b-smoke",
    family="vlm",
    n_layers=10,             # 2 periods of (4 self + 1 cross)
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab_size=256,
    rope_theta=500_000.0,
    cross_attn_layers=(3, 8),
    n_vis_tokens=17,
    vis_dim=48,
)
