"""hymba-1.5b — [hybrid] 32L d1600 25H (GQA kv=5) d_ff 5504 vocab 32001,
ssm_state=16; parallel attention + mamba heads per layer, sliding-window
attention except 3 global layers.  [arXiv:2411.13676; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    n_layers=32,
    d_model=1600,
    n_heads=25,
    n_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    attn_window=1024,
    global_layers=(0, 15, 31),
    ssm_state=16,
    ssm_head_dim=64,
    ssm_expand=2,            # d_inner 3200 → 50 mamba heads
    rope_theta=10_000.0,
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke",
    family="hybrid",
    n_layers=5,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=256,
    attn_window=16,
    global_layers=(0, 4),
    ssm_state=8,
    ssm_head_dim=16,         # d_inner 128 → 8 mamba heads
    ssm_expand=2,
    ssd_chunk=8,
)
