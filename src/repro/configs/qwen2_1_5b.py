"""qwen2-1.5b — [dense] 28L d1536 12H (GQA kv=2) d_ff 8960 vocab 151936,
GQA + QKV bias, tied embeddings.  [arXiv:2407.10671; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-1.5b",
    family="dense",
    n_layers=28,
    d_model=1536,
    n_heads=12,
    n_kv_heads=2,
    d_ff=8960,
    vocab_size=151936,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen2-1.5b-smoke",
    family="dense",
    n_layers=3,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    d_ff=192,
    vocab_size=512,
    qkv_bias=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)
