"""Assigned input shapes (same 4 for every LM architecture).

``decode_*`` / ``long_*`` lower ``serve_step`` (one new token against a
KV/SSM cache of ``seq_len``); ``train_*`` / ``prefill_*`` lower the
training / prefill forward.  ``long_500k`` requires sub-quadratic decode
and only applies to SSM/hybrid archs (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def shapes_for(cfg) -> list[ShapeSpec]:
    """The shape cells that apply to an architecture (skips noted in DESIGN)."""
    out = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.supports_long_context:
        out.append(SHAPES["long_500k"])
    return out
