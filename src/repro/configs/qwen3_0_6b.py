"""qwen3-0.6b — [dense] 28L d1024 16H (GQA kv=8) d_ff 3072 vocab 151936,
qk_norm + decoupled head_dim 128, tied embeddings.  [hf:Qwen/Qwen3-8B; hf]
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-0.6b",
    family="dense",
    n_layers=28,
    d_model=1024,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=3072,
    vocab_size=151936,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)

SMOKE = ModelConfig(
    name="qwen3-0.6b-smoke",
    family="dense",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=128,
    vocab_size=512,
    qk_norm=True,
    tie_embeddings=True,
    rope_theta=1_000_000.0,
)
