"""Public wrappers for the fused counter-rule (explicit-Δt STDP) kernels.

Bridges rule-level state (per-neuron last-spike counter words, STDPParams)
to the raw Pallas kernels, padding neuron / patch-row / lane axes with the
shared helpers in ``repro.kernels.dispatch`` exactly like the ``itp_stdp``
packages.  Zero padding is exact here because every contribution a padded
element could make is spike-gated: padded rows and columns carry no spikes,
and the out-of-range weight cells are sliced away — a zero counter word in
the pad region (nominally "spiked last step") can never reach a surviving
output cell.

The storage format is the counter twin of the packed uint8 history words:
**one uint8 word per neuron**, holding the saturating last-spike counter
(``repro.plasticity.rules.CounterRule.readout_packed``).  It crosses
shard_map and enters the kernel exactly like the packed history words of
the intrinsic-timing rules — same (n,) uint8 shape, same axis-0 sharding.

``interpret=None`` derives the interpreter flag from the host
(``repro.kernels.dispatch.default_interpret``): compiled on accelerators,
interpreter only where nothing else runs (CPU).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.stdp import STDPParams
from repro.kernels.dispatch import LANE, SUBLANE, default_interpret
from repro.kernels.dispatch import pad_axis as _pad_axis
from repro.kernels.dispatch import round_up as _round_up
from repro.kernels.itp_counter.kernel import counter_conv_delta, counter_stdp_update
from repro.kernels.itp_counter.ref import counter_conv_delta_ref, counter_stdp_update_ref

# one uint8 word per neuron: the saturating counter must fit the word
MAX_COUNTER_DEPTH = 255


def _tile(padded: int) -> int:
    """Largest of (256, LANE) that divides the padded (LANE-multiple) dim."""
    return 256 if padded % 256 == 0 else LANE


def _resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else interpret


def _check_depth(depth: int) -> None:
    if depth > MAX_COUNTER_DEPTH:
        raise ValueError(f"counter words are uint8: depth must be <= {MAX_COUNTER_DEPTH}")


def counter_weight_update(
    w: jax.Array,
    pre_spike: jax.Array,
    post_spike: jax.Array,
    pre_words: jax.Array,
    post_words: jax.Array,
    params: STDPParams,
    *,
    depth: int,
    window: str,
    eta: float = 1.0,
    w_min: float = 0.0,
    w_max: float = 1.0,
    use_kernel: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused explicit-Δt STDP update from per-neuron counter words.

    ``pre_words``/``post_words`` are one uint8 saturating last-spike
    counter per neuron; semantics match the reference
    ``CounterRule.delta`` datapath followed by the clipped accumulate
    (validated by tests/test_counter_backend.py).
    """
    _check_depth(depth)
    n_pre, n_post = w.shape
    if not use_kernel:
        return counter_stdp_update_ref(
            w,
            pre_spike,
            post_spike,
            pre_words,
            post_words,
            depth=depth,
            window=window,
            a_plus=params.a_plus,
            a_minus=params.a_minus,
            tau_plus=params.tau_plus,
            tau_minus=params.tau_minus,
            eta=eta,
            w_min=w_min,
            w_max=w_max,
        )

    p_pre = _round_up(n_pre, LANE)
    p_post = _round_up(n_post, LANE)
    out = counter_stdp_update(
        _pad_axis(_pad_axis(w, p_pre, 0), p_post, 1),
        _pad_axis(pre_spike.astype(jnp.float32), p_pre, 0),
        _pad_axis(post_spike.astype(jnp.float32), p_post, 0),
        _pad_axis(pre_words.astype(jnp.uint8), p_pre, 0),
        _pad_axis(post_words.astype(jnp.uint8), p_post, 0),
        depth=depth,
        window=window,
        a_plus=params.a_plus,
        a_minus=params.a_minus,
        tau_plus=params.tau_plus,
        tau_minus=params.tau_minus,
        eta=eta,
        w_min=w_min,
        w_max=w_max,
        tile_pre=_tile(p_pre),
        tile_post=_tile(p_post),
        interpret=_resolve_interpret(interpret),
    )
    return out[:n_pre, :n_post]


def counter_synapse_delta(
    pre_spike: jax.Array,
    post_spike: jax.Array,
    pre_words: jax.Array,
    post_words: jax.Array,
    params: STDPParams,
    *,
    depth: int,
    window: str,
    use_kernel: bool = True,
    interpret: bool | None = None,
) -> jax.Array:
    """Raw Δw (pre × post) from counter words — no clip, no ``w``.

    The counter twin of ``itp_stdp.ops.synapse_delta``: batched callers
    (the SNN fc layers) vmap this over the batch, accumulate, and apply
    clip/quantise once — reuses the fused kernel with a zero weight tile
    and an unbounded clip window.
    """
    n_pre = pre_words.shape[-1]
    n_post = post_words.shape[-1]
    zero_w = jnp.zeros((n_pre, n_post), jnp.float32)
    return counter_weight_update(
        zero_w,
        pre_spike,
        post_spike,
        pre_words,
        post_words,
        params,
        depth=depth,
        window=window,
        eta=1.0,
        w_min=float("-inf"),
        w_max=float("inf"),
        use_kernel=use_kernel,
        interpret=interpret,
    )


def conv_counter_synapse_delta(
    pre_patches: jax.Array,
    post_spikes: jax.Array,
    pre_words: jax.Array,
    post_words: jax.Array,
    params: STDPParams,
    *,
    depth: int,
    window: str,
    use_kernel: bool = True,
    interpret: bool | None = None,
    tile_m: int = 128,
) -> jax.Array:
    """Raw (K, C) conv-layer delta from im2col'd counter words.

    ``pre_words`` (M, K) / ``post_words`` (M, C) carry one uint8 counter
    word per patch element / output neuron, gathered into the im2col
    layout by ``itp_stdp_conv.ops.im2col_words_2d/1d`` (the dtype-
    preserving gather — the window readout commutes with it).  Callers
    apply the eta / (B · P) normalisation, clip, and quantisation, the
    same contract as ``conv_synapse_delta``.
    """
    _check_depth(depth)
    m, kk = pre_patches.shape
    cc = post_spikes.shape[1]
    if not use_kernel:
        return counter_conv_delta_ref(
            pre_patches,
            post_spikes,
            pre_words,
            post_words,
            depth=depth,
            window=window,
            a_plus=params.a_plus,
            a_minus=params.a_minus,
            tau_plus=params.tau_plus,
            tau_minus=params.tau_minus,
        )

    tm = min(tile_m, _round_up(m, SUBLANE))
    pm = _round_up(m, tm)
    pk = _round_up(kk, LANE)
    pc = _round_up(cc, LANE)
    out = counter_conv_delta(
        _pad_axis(_pad_axis(pre_patches, pm, 0), pk, 1),
        _pad_axis(_pad_axis(post_spikes, pm, 0), pc, 1),
        _pad_axis(_pad_axis(pre_words.astype(jnp.uint8), pm, 0), pk, 1),
        _pad_axis(_pad_axis(post_words.astype(jnp.uint8), pm, 0), pc, 1),
        depth=depth,
        window=window,
        a_plus=params.a_plus,
        a_minus=params.a_minus,
        tau_plus=params.tau_plus,
        tau_minus=params.tau_minus,
        tile_m=tm,
        interpret=_resolve_interpret(interpret),
    )
    return out[:kk, :cc]
