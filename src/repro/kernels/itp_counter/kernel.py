"""Fused counter-rule (explicit-Δt STDP) Pallas kernels.

The conventional learning datapath the paper measures ITP-STDP against
(Tables III-V), implemented the way prior explicit-Δt accelerators do it
on-chip: the per-neuron last-spike counter word is read once from HBM,
the per-pair timing difference is formed **in-register** by broadcasting
the counter across the synapse tile, and the rule's window function is
evaluated per pair, fused with the XOR pair gate and the clipped weight
read-modify-write — one HBM round-trip per weight tile, exactly like the
``itp_stdp`` kernel it is benchmarked against.

What differs per window is the per-pair arithmetic the tile pays for:

  * ``exact``  — a base-e ``exp`` per synapse (the O(n²) transcendental
                 the intrinsic-timing register read eliminates);
  * ``linear`` — a PWL multiply+clip per synapse;
  * ``imstdp`` — a LUT read per synapse: the table lives in **SMEM**
                 (one scalar row per valid delay, built host-side by
                 ``ref.window_lut``) and is applied as a depth-long
                 select chain over the integer delay grid — scalar reads,
                 no vector gather.

Layout choices (mirroring the dense ``itp_stdp`` kernel): counters arrive
as ``(1, T)`` uint8 words with the neuron axis on the 128-wide lane
dimension; the weight tile stays resident in VMEM for the fused RMW; the
conv variant contracts the patch-row axis on the MXU with the same
accumulate-into-out_ref schedule as ``itp_stdp_conv``.

Counter rules are nearest-neighbour by construction (one counter holds
one spike time), so there is no pairing switch here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.itp_counter.ref import window_exact, window_linear, window_lut


def counter_delays(words: jax.Array, depth: int) -> tuple[jax.Array, jax.Array]:
    """In-register Δt formation: uint8 counter words → (delays, validity).

    A word at value t means the neuron last spiked t steps ago; words
    saturate at ``depth`` (one past the last valid delay), so the validity
    gate is ``t <= depth - 1``.  Every kernel body routes through this —
    the round-trip (counter → word → in-register delay + validity) is
    pinned by the property tests in tests/test_counter_backend.py.
    """
    t = words.astype(jnp.int32)
    return t, (t <= depth - 1).astype(jnp.float32)


def _pair_window(
    dt: jax.Array,
    valid: jax.Array,
    lut_ref,
    lut_row: int,
    *,
    window: str,
    amplitude: float,
    tau: float,
    depth: int,
) -> jax.Array:
    """Per-pair window magnitude on an integer-delay tile, validity-gated.

    ``dt``/``valid`` are the broadcast (tile-shaped) delay and validity —
    the window is evaluated once per synapse, which is the measured-cost
    contract of the counter datapath (benchmarks/rule_cost.py).
    """
    # exact/linear evaluate the shared ref.py callables in the kernel body
    # (plain jnp, so they trace under Pallas) — ref.py stays the single
    # owner of the window semantics; only the imstdp SMEM read diverges
    # from its LUT-gather reference by construction
    if window == "exact":
        mag = window_exact(dt.astype(jnp.float32), amplitude, tau, depth)
    elif window == "linear":
        mag = window_linear(dt.astype(jnp.float32), amplitude, tau, depth)
    elif window == "imstdp":
        # SMEM LUT read: a depth-long select chain over the integer grid —
        # each step reads one scalar lut_ref[lut_row, k] from SMEM and
        # selects it where the pair's delay matches
        mag = jnp.zeros(dt.shape, jnp.float32)
        for k in range(depth):
            mag = jnp.where(dt == k, lut_ref[lut_row, k], mag)
    else:
        raise ValueError(f"unknown counter window {window!r}")
    return mag * valid


def _counter_stdp_kernel(
    pre_spike_ref,
    post_spike_ref,
    pre_word_ref,
    post_word_ref,
    lut_ref,
    w_ref,
    out_ref,
    *,
    depth: int,
    window: str,
    a_plus: float,
    a_minus: float,
    tau_plus: float,
    tau_minus: float,
    eta: float,
    w_min: float,
    w_max: float,
):
    tp = pre_word_ref.shape[1]
    tq = post_word_ref.shape[1]
    pre_t, pre_valid = counter_delays(pre_word_ref[...], depth)  # (1, TP)
    post_t, post_valid = counter_delays(post_word_ref[...], depth)  # (1, TQ)

    # per-pair Δt: broadcast the counter words across the synapse tile —
    # LTP pairs read the presynaptic delay, LTD pairs the postsynaptic one
    dt_ltp = jnp.broadcast_to(pre_t[0][:, None], (tp, tq))
    dt_ltd = jnp.broadcast_to(post_t[0][None, :], (tp, tq))
    ltp_mag = _pair_window(
        dt_ltp,
        jnp.broadcast_to(pre_valid[0][:, None], (tp, tq)),
        lut_ref,
        0,
        window=window,
        amplitude=a_plus,
        tau=tau_plus,
        depth=depth,
    )
    ltd_mag = _pair_window(
        dt_ltd,
        jnp.broadcast_to(post_valid[0][None, :], (tp, tq)),
        lut_ref,
        1,
        window=window,
        amplitude=a_minus,
        tau=tau_minus,
        depth=depth,
    )

    # XOR/AND control logic (§V-A), arithmetic form on {0,1}
    pre_s = pre_spike_ref[...].astype(jnp.float32)  # (1, TP)
    post_s = post_spike_ref[...].astype(jnp.float32)  # (1, TQ)
    xor = pre_s[0, :, None] + post_s[0, None, :] - 2.0 * pre_s[0, :, None] * post_s[0, None, :]
    ltp_en = xor * post_s[0, None, :]  # post fired alone
    ltd_en = xor * pre_s[0, :, None]  # pre fired alone

    dw = ltp_en * ltp_mag - ltd_en * ltd_mag
    out_ref[...] = jnp.clip(w_ref[...] + eta * dw, w_min, w_max)


@functools.partial(
    jax.jit,
    static_argnames=(
        "depth",
        "window",
        "a_plus",
        "a_minus",
        "tau_plus",
        "tau_minus",
        "eta",
        "w_min",
        "w_max",
        "tile_pre",
        "tile_post",
        "interpret",
    ),
)
def counter_stdp_update(
    w: jax.Array,
    pre_spike: jax.Array,
    post_spike: jax.Array,
    pre_words: jax.Array,
    post_words: jax.Array,
    *,
    depth: int,
    window: str,
    a_plus: float,
    a_minus: float,
    tau_plus: float,
    tau_minus: float,
    eta: float = 1.0,
    w_min: float = 0.0,
    w_max: float = 1.0,
    tile_pre: int = 256,
    tile_post: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """Fused explicit-Δt STDP weight update from per-neuron counter words.

    Args:
      w:          (n_pre, n_post) float32 synapse matrix.
      pre_spike:  (n_pre,)  current-step spikes {0,1}.
      post_spike: (n_post,) current-step spikes {0,1}.
      pre_words:  (n_pre,)  uint8 last-spike counter words (t steps since
                  the last spike, saturated at ``depth``).
      post_words: (n_post,) uint8 counter words.
      depth:      history window — delays ``0..depth-1`` are live, the
                  saturated word value ``depth`` is gated to zero.
      window:     'exact' | 'linear' | 'imstdp' (see module docstring).
      a_plus/a_minus/tau_plus/tau_minus: the STDP window parameters.
      interpret:  run the kernel body in interpret mode (CPU validation);
                  the default False targets real accelerator hardware.

    Returns the updated, clipped weight matrix.
    """
    n_pre, n_post = w.shape
    tp = min(tile_pre, n_pre)
    tq = min(tile_post, n_post)
    if n_pre % tp or n_post % tq:
        raise ValueError(f"tile sizes ({tp},{tq}) must divide ({n_pre},{n_post})")

    lut = jnp.stack([window_lut(a_plus, tau_plus, depth), window_lut(a_minus, tau_minus, depth)])
    grid = (n_pre // tp, n_post // tq)
    kern = functools.partial(
        _counter_stdp_kernel,
        depth=depth,
        window=window,
        a_plus=a_plus,
        a_minus=a_minus,
        tau_plus=tau_plus,
        tau_minus=tau_minus,
        eta=eta,
        w_min=w_min,
        w_max=w_max,
    )
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tp), lambda i, j: (0, i)),  # pre_spike
            pl.BlockSpec((1, tq), lambda i, j: (0, j)),  # post_spike
            pl.BlockSpec((1, tp), lambda i, j: (0, i)),  # pre counter words
            pl.BlockSpec((1, tq), lambda i, j: (0, j)),  # post counter words
            pl.BlockSpec(  # window LUT: scalar rows in SMEM
                (2, depth),
                lambda i, j: (0, 0),
                memory_space=pltpu.TPUMemorySpace.SMEM,
            ),
            pl.BlockSpec((tp, tq), lambda i, j: (i, j)),  # w
        ],
        out_specs=pl.BlockSpec((tp, tq), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pre, n_post), jnp.float32),
        interpret=interpret,
    )(
        pre_spike.reshape(1, n_pre).astype(jnp.float32),
        post_spike.reshape(1, n_post).astype(jnp.float32),
        pre_words.reshape(1, n_pre).astype(jnp.uint8),
        post_words.reshape(1, n_post).astype(jnp.uint8),
        lut.astype(jnp.float32),
        w.astype(jnp.float32),
    )


def _counter_conv_kernel(
    pre_ref,
    post_ref,
    pre_word_ref,
    post_word_ref,
    lut_ref,
    out_ref,
    *,
    depth: int,
    window: str,
    a_plus: float,
    a_minus: float,
    tau_plus: float,
    tau_minus: float,
):
    pre = pre_ref[...].astype(jnp.float32)  # (TM, K)
    post = post_ref[...].astype(jnp.float32)  # (TM, C)
    pre_t, pre_valid = counter_delays(pre_word_ref[...], depth)  # (TM, K)
    post_t, post_valid = counter_delays(post_word_ref[...], depth)  # (TM, C)

    # per-(patch element) window evaluation — each element pays the window
    # arithmetic before the pair-gated patch-row contraction, mirroring the
    # dense kernel's per-pair cost on the im2col layout
    ltp_mag = _pair_window(
        pre_t,
        pre_valid,
        lut_ref,
        0,
        window=window,
        amplitude=a_plus,
        tau=tau_plus,
        depth=depth,
    )
    ltd_mag = _pair_window(
        post_t,
        post_valid,
        lut_ref,
        1,
        window=window,
        amplitude=a_minus,
        tau=tau_minus,
        depth=depth,
    )

    # XOR/AND pair gate: potentiate where post fired alone, depress where
    # pre fired alone; contract the patch-row axis on the MXU
    contract = (((0,), (0,)), ((), ()))
    ltp_term = (1.0 - pre) * ltp_mag  # (TM, K)
    ltd_term = (1.0 - post) * ltd_mag  # (TM, C)
    dw_ltp = jax.lax.dot_general(ltp_term, post, contract, preferred_element_type=jnp.float32)
    dw_ltd = jax.lax.dot_general(pre, ltd_term, contract, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += dw_ltp - dw_ltd


@functools.partial(
    jax.jit,
    static_argnames=(
        "depth",
        "window",
        "a_plus",
        "a_minus",
        "tau_plus",
        "tau_minus",
        "tile_m",
        "interpret",
    ),
)
def counter_conv_delta(
    pre_patches: jax.Array,
    post_spikes: jax.Array,
    pre_words: jax.Array,
    post_words: jax.Array,
    *,
    depth: int,
    window: str,
    a_plus: float,
    a_minus: float,
    tau_plus: float,
    tau_minus: float,
    tile_m: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Patch-level fused explicit-Δt STDP conv weight delta.

    Args:
      pre_patches: (M, K) im2col spike patches, M = batch x output positions.
      post_spikes: (M, C) current-step output spikes.
      pre_words:   (M, K) uint8 counter words in the same im2col patch
                   layout as ``pre_patches`` (window readout commutes with
                   the gather — each element carries its source pixel's
                   last-spike delay).
      post_words:  (M, C) uint8 output-neuron counter words.
      depth/window/a_plus/a_minus/tau_plus/tau_minus: as in
                   :func:`counter_stdp_update`.
      tile_m:      patch rows per grid step; must divide M.
      interpret:   run through the Pallas interpreter (CPU validation).

    Returns the (K, C) float32 delta accumulated over all M patch rows.
    """
    m, kk = pre_patches.shape
    cc = post_spikes.shape[1]
    tm = min(tile_m, m)
    if m % tm:
        raise ValueError(f"tile_m={tm} must divide M={m}")

    lut = jnp.stack([window_lut(a_plus, tau_plus, depth), window_lut(a_minus, tau_minus, depth)])
    kern = functools.partial(
        _counter_conv_kernel,
        depth=depth,
        window=window,
        a_plus=a_plus,
        a_minus=a_minus,
        tau_plus=tau_plus,
        tau_minus=tau_minus,
    )
    return pl.pallas_call(
        kern,
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((tm, kk), lambda i: (i, 0)),  # pre patches
            pl.BlockSpec((tm, cc), lambda i: (i, 0)),  # post spikes
            pl.BlockSpec((tm, kk), lambda i: (i, 0)),  # pre counter words
            pl.BlockSpec((tm, cc), lambda i: (i, 0)),  # post counter words
            pl.BlockSpec(  # window LUT: scalar rows in SMEM
                (2, depth),
                lambda i: (0, 0),
                memory_space=pltpu.TPUMemorySpace.SMEM,
            ),
        ],
        out_specs=pl.BlockSpec((kk, cc), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((kk, cc), jnp.float32),
        interpret=interpret,
    )(
        pre_patches.astype(jnp.float32),
        post_spikes.astype(jnp.float32),
        pre_words.astype(jnp.uint8),
        post_words.astype(jnp.uint8),
        lut.astype(jnp.float32),
    )
