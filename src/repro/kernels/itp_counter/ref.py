"""Pure-jnp oracle for the fused counter-rule (explicit-Δt STDP) kernels.

The conventional datapath the paper's Tables III-V monetise: a per-neuron
last-spike counter is broadcast to every synapse, the per-pair timing
difference Δt formed, and a window function evaluated **per pair** — the
O(n²) transcendental/select work the intrinsic-timing register read
collapses to O(n).  Three windows, matching the paper's baseline hierarchy:

  * ``exact``  — base-e exponential ([26]/[28]-style original STDP)
  * ``linear`` — the PWL approximation of [24] (matched value/slope at
                 dt=0, zero at the 2τ window edge)
  * ``imstdp`` — the integer-grid LUT of [23] (counters are already
                 integer, so the lookup loses nothing — the storage/op
                 cost, not the values, is what differs from ``exact``)

This module is the single owner of the window semantics:
``repro.plasticity.rules`` evaluates the same callables on its reference
readout path, so the kernel oracle and the rule registry cannot drift.

A counter at value t means the neuron last spiked t steps ago; counters
saturate at ``depth`` (one past the last valid delay ``depth - 1``), and
the validity gate zeroes every saturated pair.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def window_exact(dt: jax.Array, amplitude: float, tau: float, depth: int) -> jax.Array:
    del depth
    return amplitude * jnp.exp(-dt / tau)


def window_linear(dt: jax.Array, amplitude: float, tau: float, depth: int) -> jax.Array:
    # PWL of [24]: matched value/slope at dt=0, zero at the 2τ window edge
    del depth
    return amplitude * jnp.clip(1.0 - dt / (2.0 * tau), 0.0, 1.0)


def window_lut(amplitude: float, tau: float, depth: int) -> jax.Array:
    """The [23] LUT on the integer delay grid: one row per valid delay.

    The validity gate zeroes everything past ``depth - 1``, so the index
    clip in :func:`window_imstdp` never aliases a live delay onto the last
    row.  This is also the table the fused kernel reads from SMEM.
    """
    return amplitude * jnp.exp(-jnp.arange(depth, dtype=jnp.float32) / tau)


def window_imstdp(dt: jax.Array, amplitude: float, tau: float, depth: int) -> jax.Array:
    lut = window_lut(amplitude, tau, depth)
    k = jnp.clip(dt.astype(jnp.int32), 0, depth - 1)
    return lut[k]


WINDOWS = {"exact": window_exact, "linear": window_linear, "imstdp": window_imstdp}


def counter_magnitudes(
    t: jax.Array, amplitude: float, tau: float, *, depth: int, window: str
) -> jax.Array:
    """Per-neuron window magnitude gated by counter validity: ``f(t)·[t<d]``."""
    valid = t <= depth - 1
    return WINDOWS[window](t.astype(jnp.float32), amplitude, tau, depth) * valid


def counter_stdp_update_ref(
    w: jax.Array,
    pre_spike: jax.Array,
    post_spike: jax.Array,
    pre_t: jax.Array,
    post_t: jax.Array,
    *,
    depth: int,
    window: str,
    a_plus: float,
    a_minus: float,
    tau_plus: float,
    tau_minus: float,
    eta: float = 1.0,
    w_min: float = 0.0,
    w_max: float = 1.0,
) -> jax.Array:
    """Reference semantics of the fused dense counter kernel.

    ``pre_t``/``post_t`` are per-neuron last-spike counters (any integer
    dtype); the Δt broadcast and the per-pair window evaluation mirror
    ``repro.plasticity.rules.CounterRule.delta`` exactly.
    """
    fn = WINDOWS[window]
    pre_t = pre_t.astype(jnp.int32)
    post_t = post_t.astype(jnp.int32)
    dt_ltp = pre_t[:, None].astype(jnp.float32)  # (n_pre, 1)
    dt_ltd = post_t[None, :].astype(jnp.float32)  # (1, n_post)
    ltp_mag = fn(dt_ltp, a_plus, tau_plus, depth) * (pre_t[:, None] <= depth - 1)
    ltd_mag = fn(dt_ltd, a_minus, tau_minus, depth) * (post_t[None, :] <= depth - 1)

    pre_s = pre_spike.astype(jnp.bool_)
    post_s = post_spike.astype(jnp.bool_)
    fire_xor = jnp.logical_xor(pre_s[:, None], post_s[None, :])
    ltp_en = jnp.logical_and(fire_xor, post_s[None, :]).astype(jnp.float32)
    ltd_en = jnp.logical_and(fire_xor, pre_s[:, None]).astype(jnp.float32)

    dw = ltp_en * ltp_mag - ltd_en * ltd_mag
    return jnp.clip(w.astype(jnp.float32) + eta * dw, w_min, w_max)


def counter_conv_delta_ref(
    pre_patches: jax.Array,
    post_spikes: jax.Array,
    pre_t: jax.Array,
    post_t: jax.Array,
    *,
    depth: int,
    window: str,
    a_plus: float,
    a_minus: float,
    tau_plus: float,
    tau_minus: float,
) -> jax.Array:
    """Reference semantics of the fused conv counter kernel.

    ``pre_t`` (M, K) carries the last-spike counter of each patch element's
    source pixel (window readout commutes with the im2col gather), ``post_t``
    (M, C) the output-neuron counters; the pair-gated patch-row contraction
    matches the history-rule conv oracle's formulation.
    """
    ltp_mag = counter_magnitudes(
        pre_t.astype(jnp.int32), a_plus, tau_plus, depth=depth, window=window
    )
    ltd_mag = counter_magnitudes(
        post_t.astype(jnp.int32), a_minus, tau_minus, depth=depth, window=window
    )
    pre = pre_patches.astype(jnp.float32)
    post = post_spikes.astype(jnp.float32)
    dw_ltp = jnp.einsum("mk,mc->kc", (1.0 - pre) * ltp_mag, post)
    dw_ltd = jnp.einsum("mk,mc->kc", pre, (1.0 - post) * ltd_mag)
    return dw_ltp - dw_ltd
