"""Pure-jnp oracle for po2 gradient (de)quantisation."""
from __future__ import annotations

import jax
import jax.numpy as jnp

BIAS = 64


def exact_exp2_int(e: jax.Array) -> jax.Array:
    """Exact 2^e for int32 e ∈ [-126, 127], by f32 exponent-field
    construction — XLA's polynomial ``exp2`` is NOT exactly 2^e even at
    integer inputs (e.g. exp2(13) → 8192.0039 on CPU), which would corrupt
    the wire format.  This is also literally the hardware decoder circuit.
    """
    bits = (e.astype(jnp.int32) + 127) << 23
    return jax.lax.bitcast_convert_type(bits, jnp.float32)


def po2_encode_ref(x: jax.Array) -> jax.Array:
    x = x.astype(jnp.float32)
    mag = jnp.abs(x)
    e = jnp.round(jnp.log2(jnp.maximum(mag, 1e-38)))
    e = jnp.clip(e, -BIAS + 1, 127 - BIAS)
    code = (e + BIAS).astype(jnp.int32)
    code = jnp.where(mag == 0.0, 0, code)
    return code | jnp.where(x < 0.0, 128, 0)


def po2_decode_ref(c: jax.Array) -> jax.Array:
    c = c.astype(jnp.int32)
    sign = jnp.where((c & 128) != 0, -1.0, 1.0)
    code = c & 127
    val = sign * exact_exp2_int(code - BIAS)
    return jnp.where(code == 0, 0.0, val)


def po2_roundtrip_ref(x: jax.Array) -> jax.Array:
    """Quantise to the nearest power of two (the ITP-STDP quantiser)."""
    return po2_decode_ref(po2_encode_ref(x))
