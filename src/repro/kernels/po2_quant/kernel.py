"""Power-of-two gradient (de)quantisation Pallas kernels.

Beyond-paper generalisation of ITP-STDP's po2 representation to the
distributed-training substrate: gradients crossing the slow inter-pod links
are compressed to  sign · 2^e  with an int8 wire format

    bit 7   : sign
    bits 0-6: biased exponent  e + BIAS   (0 encodes exact zero)

Encode:  e = round(log2 |x|) clipped to [-BIAS+1, 127-BIAS]   (round-to-
nearest in log space = round-to-nearest-po2 in linear space, the same
quantiser ITP-STDP applies to its weight updates).
Decode:  x ≈ sign · 2^(code - BIAS).

4× wire compression vs f32, 2× vs bf16; quantisation is unbiased in log
space with worst-case relative error 2^0.5-1 ≈ 41 % per element, zero-mean
over a pod's gradient population (validated in tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIAS = 64


def _encode_kernel(x_ref, o_ref):
    x = x_ref[...]
    mag = jnp.abs(x)
    # round(log2|x|): exponent of the nearest power of two
    e = jnp.round(jnp.log2(jnp.maximum(mag, 1e-38)))
    e = jnp.clip(e, -BIAS + 1, 127 - BIAS)
    code = (e + BIAS).astype(jnp.int32)
    code = jnp.where(mag == 0.0, 0, code)
    sign_bit = jnp.where(x < 0.0, 128, 0)
    o_ref[...] = (code | sign_bit).astype(jnp.int32)


def _decode_kernel(c_ref, o_ref):
    c = c_ref[...]
    sign = jnp.where((c & 128) != 0, -1.0, 1.0)
    code = c & 127
    # exact 2^e via exponent-field construction (XLA exp2 is inexact even
    # at integer points); this is the literal decoder circuit
    bits = (code - BIAS + 127) << 23
    val = sign * jax.lax.bitcast_convert_type(bits, jnp.float32)
    o_ref[...] = jnp.where(code == 0, 0.0, val)


def _elementwise_call(kern, x: jax.Array, out_dtype, *, tile: int,
                      interpret: bool) -> jax.Array:
    n = x.shape[-1]
    if n % tile:
        raise ValueError(f"tile {tile} must divide {n}")
    return pl.pallas_call(
        kern,
        grid=(n // tile,),
        in_specs=[pl.BlockSpec((1, tile), lambda i: (0, i))],
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), out_dtype),
        interpret=interpret,
    )(x.reshape(1, n))


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def po2_encode(x: jax.Array, *, tile: int = 512,
               interpret: bool = True) -> jax.Array:
    """f32 (n,) → po2 codes (n,) int32 (low byte is the wire format)."""
    return _elementwise_call(_encode_kernel, x.astype(jnp.float32),
                             jnp.int32, tile=tile, interpret=interpret)[0]


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def po2_decode(c: jax.Array, *, tile: int = 512,
               interpret: bool = True) -> jax.Array:
    """po2 codes (n,) int32 → f32 (n,)."""
    return _elementwise_call(_decode_kernel, c.astype(jnp.int32),
                             jnp.float32, tile=tile, interpret=interpret)[0]
