"""Jit'd wrappers: shape-generic po2 quantisation for gradient pytrees."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import default_interpret
from repro.kernels.po2_quant.kernel import po2_decode, po2_encode
from repro.kernels.po2_quant.ref import po2_decode_ref, po2_encode_ref

LANE = 128


def po2_quantize(x: jax.Array, *, use_kernel: bool = False,
                 interpret: bool | None = None) -> jax.Array:
    """Round every element to the nearest power of two (sign preserved).

    ``use_kernel=False`` (default) uses the jnp path — the quantiser is
    memory-bound and XLA fuses it into the surrounding collective; the
    Pallas path exists to pin the VMEM tiling on real TPU and for tests.
    ``interpret=None`` resolves via ``dispatch.default_interpret`` (R3).
    """
    if interpret is None:
        interpret = default_interpret()
    if not use_kernel:
        return po2_decode_ref(po2_encode_ref(x))
    shape = x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % LANE
    if pad:
        flat = jnp.pad(flat, (0, pad))
    codes = po2_encode(flat, tile=LANE, interpret=interpret)
    out = po2_decode(codes, tile=LANE, interpret=interpret)
    return out[:n].reshape(shape)


def po2_quantize_tree(tree, **kw):
    return jax.tree_util.tree_map(lambda g: po2_quantize(g, **kw), tree)
