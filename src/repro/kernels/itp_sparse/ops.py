"""Event-driven weight updates: gather/scatter-RMW on the touched slices.

The dense backends read every (pre, post) pair per step and XOR-gate
>= 95 % of them to zero at realistic 1-5 % spike densities; these ops
touch only the slices adjacent to actual events:

  * LTP writes the **columns** of postsynaptic neurons that fired
    (``post`` events), adding the per-row magnitude ``(1-pre)·ltp``;
  * LTD writes the **rows** of presynaptic neurons that fired (``pre``
    events), subtracting the per-column magnitude ``(1-post)·ltd``.

Because the XOR pair gate makes the two touched sets interact only on
(pre-event x post-event) cells — where both masked magnitudes are
exactly zero — the scatter sequence is *exactly* the dense
``clip(w + eta·dw)`` whenever ``w`` already lies in ``[w_min, w_max]``
(the engine invariant: inits and every update are clipped).  Parity is
pinned at ops, engine-scan and network level in
tests/test_sparse_backend.py.

Event lists come from :mod:`repro.kernels.itp_sparse.events`: static
shape ``E = event_cap(n, max_events)``, ascending indices, padded with
the out-of-range sentinel ``n`` so gathers read zeros (``mode="fill"``)
and scatters drop the padding (``mode="drop"``).  With ``max_events``
below the live event count the *highest-indexed* events are dropped —
deterministic saturation, pinned against the truncated dense formula.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.itp_sparse.events import spike_events


def sparse_weight_update(
    w: jax.Array,
    pre_spike: jax.Array,
    post_spike: jax.Array,
    ltp_mag: jax.Array,
    ltd_mag: jax.Array,
    *,
    eta: float = 1.0,
    w_min: float = 0.0,
    w_max: float = 1.0,
    max_events: int | None = None,
    pre_events: jax.Array | None = None,
    post_events: jax.Array | None = None,
) -> jax.Array:
    """Clipped event-driven RMW of the dense ``(n_pre, n_post)`` matrix.

    ``ltp_mag``/``ltd_mag`` are the per-neuron magnitudes the rule read
    from its timing state (``(n_pre,)`` / ``(n_post,)``).  Callers may
    pass precomputed event lists (the sharded engine ships global pre
    events across shard_map and translates them to tile-local indices);
    out-of-tile entries must already be remapped to an out-of-range
    sentinel so the scatter drops them.
    """
    pre = jnp.asarray(pre_spike, jnp.float32)
    post = jnp.asarray(post_spike, jnp.float32)
    if pre_events is None:
        pre_events, _ = spike_events(pre, max_events)
    if post_events is None:
        post_events, _ = spike_events(post, max_events)

    # LTP: post fired alone -> potentiate its column from the pre readout
    ltp_row = (1.0 - pre) * ltp_mag                       # (n_pre,)
    cols = jnp.take(w, post_events, axis=1, mode="fill", fill_value=0.0)
    cols = jnp.clip(cols + eta * ltp_row[:, None], w_min, w_max)
    w = w.at[:, post_events].set(cols, mode="drop")

    # LTD: pre fired alone -> depress its row from the post readout
    ltd_col = (1.0 - post) * ltd_mag                      # (n_post,)
    rows = jnp.take(w, pre_events, axis=0, mode="fill", fill_value=0.0)
    rows = jnp.clip(rows - eta * ltd_col[None, :], w_min, w_max)
    return w.at[pre_events, :].set(rows, mode="drop")


def sparse_synapse_delta(
    pre_spike: jax.Array,
    post_spike: jax.Array,
    ltp_mag: jax.Array,
    ltd_mag: jax.Array,
    *,
    max_events: int | None = None,
) -> jax.Array:
    """Raw event-driven ``(n_pre, n_post)`` Δw (no eta/clip).

    The batched SNN fc layers vmap this over samples and accumulate —
    the sparse twin of the rules' ``fused_delta_from_readout``.  Built by
    scattering the two event slices into zeros: LTP columns are *set*
    (disjoint from everything but pre-event rows, where the masked
    magnitude is zero), LTD rows are *added* (so the overlap stays
    exact).
    """
    pre = jnp.asarray(pre_spike, jnp.float32)
    post = jnp.asarray(post_spike, jnp.float32)
    pre_events, _ = spike_events(pre, max_events)
    post_events, _ = spike_events(post, max_events)
    n_pre, n_post = pre.shape[0], post.shape[0]

    dw = jnp.zeros((n_pre, n_post), jnp.float32)
    ltp_row = (1.0 - pre) * ltp_mag
    dw = dw.at[:, post_events].set(
        jnp.broadcast_to(ltp_row[:, None], (n_pre, post_events.shape[0])),
        mode="drop",
    )
    ltd_col = (1.0 - post) * ltd_mag
    return dw.at[pre_events, :].add(
        jnp.broadcast_to(-ltd_col[None, :], (pre_events.shape[0], n_post)),
        mode="drop",
    )


def sparse_conv_delta(
    pre_patches: jax.Array,
    post_spikes: jax.Array,
    pre_bits: jax.Array,
    post_bits: jax.Array,
    po2_ltp: jax.Array,
    po2_ltd: jax.Array,
    *,
    nearest: bool = True,
    max_events: int | None = None,
) -> jax.Array:
    """Event-driven ``(K, C)`` conv delta: im2col on gathered rows only.

    A patch row contributes iff it carries *current-step* activity on
    either side (LTP needs a post spike in the row, LTD a pre spike —
    history bits alone contribute nothing through the pair gate), so the
    active-row event list gathers only those rows of the im2col operands
    and the oracle runs on the ``(E, ·)`` subset.  Padding rows gather as
    all-zero and contribute exactly zero, so the result equals the dense
    ``itp_stdp_conv_delta_ref`` whenever every active row fits the cap.
    """
    from repro.kernels.itp_stdp_conv.ref import itp_stdp_conv_delta_ref

    pre = jnp.asarray(pre_patches, jnp.float32)           # (M, K)
    post = jnp.asarray(post_spikes, jnp.float32)          # (M, C)
    active = jnp.any(pre != 0, axis=1) | jnp.any(post != 0, axis=1)
    rows, _ = spike_events(active, max_events)            # (E,)

    gather = lambda a, axis: jnp.take(a, rows, axis=axis, mode="fill", fill_value=0)
    return itp_stdp_conv_delta_ref(
        gather(pre, 0),
        gather(post, 0),
        gather(jnp.asarray(pre_bits, jnp.float32), 1),    # (depth, E, K)
        gather(jnp.asarray(post_bits, jnp.float32), 1),   # (depth, E, C)
        po2_ltp,
        po2_ltd,
        nearest=nearest,
    )
