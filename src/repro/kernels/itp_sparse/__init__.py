"""Event-driven sparse weight-update datapath (``backend="sparse"``).

Static-shape spike-event lists (``events``) gate gather/scatter updates
of only the touched weight slices (``ops``) — the event-queue view of
the paper's premise that a dense STDP datapath wastes >= 95 % of its
work at realistic spike densities.  Not a Pallas package: the datapath
is pure jnp, selected per config via ``BACKENDS`` in
``repro.kernels.dispatch`` and routed through the rule-owned sparse
hooks in ``repro.plasticity``.
"""

from repro.kernels.itp_sparse.events import event_cap, spike_events, word_events
from repro.kernels.itp_sparse.ops import (
    sparse_conv_delta,
    sparse_synapse_delta,
    sparse_weight_update,
)
