"""Static-shape spike-event extraction for the event-driven backend.

The whole sparse datapath hinges on one primitive: turn a {0,1} activity
vector (or a bit slot of the packed uint8 history words) into a
**jit-stable** index list.  ``jnp.where`` with a static ``size`` gives
exactly the semantics the hardware event queue would: the first
``max_events`` active indices in ascending neuron order, padded with the
out-of-range sentinel ``n`` — so downstream gathers (``mode="fill"``)
read zeros and scatters (``mode="drop"``) skip the padding without any
dynamic shapes.  Saturation is deterministic: events beyond the cap are
the *highest-indexed* ones and are dropped (pinned by
tests/test_sparse_events.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def event_cap(n: int, max_events: int | None) -> int:
    """The static event-list length for a population of ``n`` neurons.

    ``None`` means uncapped (every neuron could fire: cap = n); a cap
    larger than ``n`` is clamped — the list never needs more slots than
    neurons.
    """
    if max_events is None:
        return n
    if max_events < 1:
        raise ValueError(f"max_events must be >= 1, got {max_events}")
    return min(int(max_events), n)


def spike_events(
    spikes: jax.Array, max_events: int | None = None
) -> tuple[jax.Array, jax.Array]:
    """Event list of a ``(n,)`` {0,1} spike vector.

    Returns ``(idx, count)``: ``idx`` is int32 ``(E,)`` with
    ``E = event_cap(n, max_events)`` — the first ``E`` active indices in
    ascending order, padded with the sentinel ``n`` — and ``count`` the
    number of valid (non-padding) entries, saturating at ``E``.
    """
    spikes = jnp.asarray(spikes)
    n = spikes.shape[-1]
    cap = event_cap(n, max_events)
    (idx,) = jnp.where(spikes != 0, size=cap, fill_value=n)
    idx = idx.astype(jnp.int32)
    count = jnp.minimum(jnp.sum(spikes != 0), cap).astype(jnp.int32)
    return idx, count


def word_events(
    words: jax.Array,
    depth: int,
    max_events: int | None = None,
    *,
    slot: int = 0,
) -> tuple[jax.Array, jax.Array]:
    """Event list of one register slot of packed uint8 history words.

    ``words`` is the ``(n,)`` uint8 register file
    (``repro.core.history.pack_words``: MSB = most recent, depth <= 8);
    ``slot`` selects the register position k (0 = most recent step), i.e.
    word bit ``7 - slot``.  Returns the same ``(idx, count)`` contract as
    :func:`spike_events` for the neurons whose slot-k bit is set.
    """
    if not 0 <= slot < depth:
        raise ValueError(f"slot must be in [0, {depth}), got {slot}")
    if depth > 8:
        raise ValueError("word_events reads packed words (depth <= 8)")
    words = jnp.asarray(words, jnp.uint8)
    bit = (words >> jnp.uint8(7 - slot)) & jnp.uint8(1)
    return spike_events(bit, max_events)
