"""Pure-jnp oracle for the ITP-STDP kernel (mirrors repro.core.stdp)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def itp_stdp_update_ref(w: jax.Array,
                        pre_spike: jax.Array, post_spike: jax.Array,
                        pre_hist: jax.Array, post_hist: jax.Array,
                        po2_ltp: jax.Array, po2_ltd: jax.Array,
                        *,
                        nearest: bool = True,
                        eta: float = 1.0,
                        w_min: float = 0.0,
                        w_max: float = 1.0) -> jax.Array:
    """Reference semantics of the fused kernel, shapes as in kernel.py."""
    pre_bits = pre_hist.astype(jnp.float32)     # (depth, n_pre)
    post_bits = post_hist.astype(jnp.float32)   # (depth, n_post)
    if nearest:
        pre_bits = pre_bits * (jnp.cumsum(pre_bits, axis=0) == 1.0)
        post_bits = post_bits * (jnp.cumsum(post_bits, axis=0) == 1.0)

    ltp_mag = po2_ltp.astype(jnp.float32) @ pre_bits    # (n_pre,)
    ltd_mag = po2_ltd.astype(jnp.float32) @ post_bits   # (n_post,)

    pre_s = pre_spike.astype(jnp.bool_)
    post_s = post_spike.astype(jnp.bool_)
    fire_xor = jnp.logical_xor(pre_s[:, None], post_s[None, :])
    ltp_en = jnp.logical_and(fire_xor, post_s[None, :]).astype(jnp.float32)
    ltd_en = jnp.logical_and(fire_xor, pre_s[:, None]).astype(jnp.float32)

    dw = ltp_en * ltp_mag[:, None] - ltd_en * ltd_mag[None, :]
    return jnp.clip(w.astype(jnp.float32) + eta * dw, w_min, w_max)
