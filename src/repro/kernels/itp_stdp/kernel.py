"""Fused ITP-STDP synapse-update Pallas kernel.

TPU adaptation of the paper's learning-engine datapath (Figs. 9-11):

  FPGA: shift-register read → priority encode → 2's-complement → adder
  TPU : bitplane dot with the po2 place-value vector (VPU/MXU) → outer
        LTP/LTD gating (the XOR/AND control logic) → fused w += Δw, clip

Layout choices (HW-codesign reasoning):
  * spike histories are stored **depth-major** ``(depth, N)`` so the neuron
    axis sits on the 128-wide lane dimension and the (≤8)-deep history on
    the sublane dimension — the po2 read is an 8-element reduction per lane,
    which the Mosaic compiler keeps entirely in VREGs;
  * the weight tile ``(TP, TQ)`` lives in VMEM for the whole fused
    read-modify-write — one HBM round-trip per tile instead of the three
    (read Δw operands, read w, write w) a composed implementation costs;
  * LTP/LTD magnitudes are rank-1 per tile row/col, so Δw is an outer
    product accumulate — MXU-aligned when TP, TQ are multiples of 8/128.

The kernel covers both pairing modes of §II-B with one code path: the
nearest-neighbour MSB mask (Fig. 11) is ``bits & (cumsum(bits) == 1)``,
the all-to-all fixed-point read (Fig. 3) uses the raw bits; both then dot
with the po2 vector, which carries the place values 2^(-k/τ') (place value
2^-k exactly in the hardware regime τ' = 1).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack_bits(words: jax.Array, depth: int) -> jax.Array:
    """In-register bitplane unpack: (1, T) uint8 words → (depth, T) f32.

    The shift+mask per depth slot of the paper's 8-bit register read (eq. 2
    / Fig. 3): bit k of the logical register sits at word bit ``7 - k``
    (MSB = most recent, ``repro.core.history.pack_words``).  Stays entirely
    in VREGs — the only HBM traffic is the one byte per neuron.
    """
    w = words.astype(jnp.int32)
    planes = [(w >> (7 - k)) & 1 for k in range(depth)]
    return jnp.concatenate(planes, axis=0).astype(jnp.float32)


def _stdp_body(pre_bits, post_bits, pre_spike_ref, post_spike_ref,
               po2_ltp_ref, po2_ltd_ref, w_ref, out_ref, *,
               nearest: bool, eta: float, w_min: float, w_max: float):
    """Shared fused datapath: po2 read → XOR pair gate → clipped RMW.

    Both kernel variants (bitplane-fed and packed-word-fed) route through
    this body, so the packed path is bit-identical to the unpacked one by
    construction.
    """
    if nearest:
        # Fig. 11 MSB mask: keep only the first '1' scanning most-recent-first
        pre_bits = pre_bits * (jnp.cumsum(pre_bits, axis=0) == 1.0)
        post_bits = post_bits * (jnp.cumsum(post_bits, axis=0) == 1.0)

    # po2 read: (1, depth) @ (depth, T) -> (1, T); the 'register read IS the
    # weight update' step.  po2 vectors include the A± amplitudes.
    ltp_mag = po2_ltp_ref[...] @ pre_bits        # (1, TP)
    ltd_mag = po2_ltd_ref[...] @ post_bits       # (1, TQ)

    # XOR/AND control logic (§V-A): update only when exactly one side fired
    pre_s = pre_spike_ref[...].astype(jnp.float32)     # (1, TP)
    post_s = post_spike_ref[...].astype(jnp.float32)   # (1, TQ)
    fire_xor = pre_s[0, :, None] + post_s[0, None, :] \
             - 2.0 * pre_s[0, :, None] * post_s[0, None, :]   # XOR on {0,1}
    ltp_en = fire_xor * post_s[0, None, :]       # post fired alone
    ltd_en = fire_xor * pre_s[0, :, None]        # pre fired alone

    dw = ltp_en * ltp_mag[0, :, None] - ltd_en * ltd_mag[0, None, :]
    out_ref[...] = jnp.clip(w_ref[...] + eta * dw, w_min, w_max)


def _stdp_kernel(pre_spike_ref, post_spike_ref, pre_hist_ref, post_hist_ref,
                 po2_ltp_ref, po2_ltd_ref, w_ref, out_ref, *,
                 nearest: bool, eta: float, w_min: float, w_max: float):
    # (depth, TP) / (depth, TQ) bitplanes, {0,1}
    pre_bits = pre_hist_ref[...].astype(jnp.float32)
    post_bits = post_hist_ref[...].astype(jnp.float32)
    _stdp_body(pre_bits, post_bits, pre_spike_ref, post_spike_ref,
               po2_ltp_ref, po2_ltd_ref, w_ref, out_ref,
               nearest=nearest, eta=eta, w_min=w_min, w_max=w_max)


def _stdp_packed_kernel(pre_spike_ref, post_spike_ref, pre_word_ref,
                        post_word_ref, po2_ltp_ref, po2_ltd_ref, w_ref,
                        out_ref, *, depth: int, nearest: bool, eta: float,
                        w_min: float, w_max: float):
    # (1, TP) / (1, TQ) packed uint8 history words — one byte per neuron
    # crosses HBM; the bitplanes exist only in-register
    pre_bits = _unpack_bits(pre_word_ref[...], depth)     # (depth, TP)
    post_bits = _unpack_bits(post_word_ref[...], depth)   # (depth, TQ)
    _stdp_body(pre_bits, post_bits, pre_spike_ref, post_spike_ref,
               po2_ltp_ref, po2_ltd_ref, w_ref, out_ref,
               nearest=nearest, eta=eta, w_min=w_min, w_max=w_max)


@functools.partial(
    jax.jit,
    static_argnames=("nearest", "eta", "w_min", "w_max", "tile_pre",
                     "tile_post", "interpret"),
)
def itp_stdp_update(w: jax.Array,
                    pre_spike: jax.Array, post_spike: jax.Array,
                    pre_hist: jax.Array, post_hist: jax.Array,
                    po2_ltp: jax.Array, po2_ltd: jax.Array,
                    *,
                    nearest: bool = True,
                    eta: float = 1.0,
                    w_min: float = 0.0,
                    w_max: float = 1.0,
                    tile_pre: int = 256,
                    tile_post: int = 256,
                    interpret: bool = False) -> jax.Array:
    """Fused ITP-STDP weight update.

    Args:
      w:          (n_pre, n_post) float32 synapse matrix.
      pre_spike:  (n_pre,)  current-step spikes {0,1}.
      post_spike: (n_post,) current-step spikes {0,1}.
      pre_hist:   (depth, n_pre)  bitplanes, k=0 row = most recent.
      post_hist:  (depth, n_post) bitplanes.
      po2_ltp:    (depth,) LTP read vector  A+·2^(-k/τ').
      po2_ltd:    (depth,) LTD read vector  A-·2^(-k/τ').
      nearest:    nearest-neighbour (True) or all-to-all (False) pairing.
      interpret:  run the kernel body in interpret mode (CPU validation);
                  the default False targets real accelerator hardware.

    Returns the updated, clipped weight matrix.
    """
    n_pre, n_post = w.shape
    depth = pre_hist.shape[0]
    tp = min(tile_pre, n_pre)
    tq = min(tile_post, n_post)
    if n_pre % tp or n_post % tq:
        raise ValueError(f"tile sizes ({tp},{tq}) must divide ({n_pre},{n_post})")

    grid = (n_pre // tp, n_post // tq)
    kern = functools.partial(_stdp_kernel, nearest=nearest, eta=eta,
                             w_min=w_min, w_max=w_max)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tp), lambda i, j: (0, i)),        # pre_spike
            pl.BlockSpec((1, tq), lambda i, j: (0, j)),        # post_spike
            pl.BlockSpec((depth, tp), lambda i, j: (0, i)),    # pre_hist
            pl.BlockSpec((depth, tq), lambda i, j: (0, j)),    # post_hist
            pl.BlockSpec((1, depth), lambda i, j: (0, 0)),     # po2_ltp
            pl.BlockSpec((1, depth), lambda i, j: (0, 0)),     # po2_ltd
            pl.BlockSpec((tp, tq), lambda i, j: (i, j)),       # w
        ],
        out_specs=pl.BlockSpec((tp, tq), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pre, n_post), jnp.float32),
        interpret=interpret,
    )(
        pre_spike.reshape(1, n_pre).astype(jnp.float32),
        post_spike.reshape(1, n_post).astype(jnp.float32),
        pre_hist.astype(jnp.float32),
        post_hist.astype(jnp.float32),
        po2_ltp.reshape(1, depth).astype(jnp.float32),
        po2_ltd.reshape(1, depth).astype(jnp.float32),
        w.astype(jnp.float32),
    )


@functools.partial(
    jax.jit,
    static_argnames=("depth", "nearest", "eta", "w_min", "w_max", "tile_pre",
                     "tile_post", "interpret"),
)
def itp_stdp_update_packed(w: jax.Array,
                           pre_spike: jax.Array, post_spike: jax.Array,
                           pre_words: jax.Array, post_words: jax.Array,
                           po2_ltp: jax.Array, po2_ltd: jax.Array,
                           *,
                           depth: int,
                           nearest: bool = True,
                           eta: float = 1.0,
                           w_min: float = 0.0,
                           w_max: float = 1.0,
                           tile_pre: int = 256,
                           tile_post: int = 256,
                           interpret: bool = False) -> jax.Array:
    """Fused ITP-STDP update fed by packed uint8 history words.

    The storage-format variant of :func:`itp_stdp_update`: instead of
    ``(depth, N)`` float32 bitplanes (``4·depth`` bytes of HBM traffic per
    neuron) the kernel reads **one uint8 word per neuron** — the hardware
    register file of the paper (Figs. 3/11) — and unpacks the bitplanes
    in-register (shift+mask per depth slot) before the identical po2 dot
    and XOR pair-gate.  Bit-identical to the unpacked kernel by
    construction (shared ``_stdp_body``).

    Args:
      w:          (n_pre, n_post) float32 synapse matrix.
      pre_spike:  (n_pre,)  current-step spikes {0,1}.
      post_spike: (n_post,) current-step spikes {0,1}.
      pre_words:  (n_pre,)  uint8 packed registers, MSB = most recent
                  (``repro.core.history.pack_words``).
      post_words: (n_post,) uint8 packed registers.
      po2_ltp:    (depth,) LTP read vector  A+·2^(-k/τ').
      po2_ltd:    (depth,) LTD read vector  A-·2^(-k/τ').
      depth:      logical register depth (≤ 8).
      nearest:    nearest-neighbour (True) or all-to-all (False) pairing.
      interpret:  run the kernel body in interpret mode (CPU validation);
                  the default False targets real accelerator hardware.

    Returns the updated, clipped weight matrix.
    """
    if depth > 8:
        raise ValueError("packed history words support depth <= 8")
    n_pre, n_post = w.shape
    tp = min(tile_pre, n_pre)
    tq = min(tile_post, n_post)
    if n_pre % tp or n_post % tq:
        raise ValueError(f"tile sizes ({tp},{tq}) must divide ({n_pre},{n_post})")

    grid = (n_pre // tp, n_post // tq)
    kern = functools.partial(_stdp_packed_kernel, depth=depth,
                             nearest=nearest, eta=eta, w_min=w_min,
                             w_max=w_max)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tp), lambda i, j: (0, i)),        # pre_spike
            pl.BlockSpec((1, tq), lambda i, j: (0, j)),        # post_spike
            pl.BlockSpec((1, tp), lambda i, j: (0, i)),        # pre_words
            pl.BlockSpec((1, tq), lambda i, j: (0, j)),        # post_words
            pl.BlockSpec((1, depth), lambda i, j: (0, 0)),     # po2_ltp
            pl.BlockSpec((1, depth), lambda i, j: (0, 0)),     # po2_ltd
            pl.BlockSpec((tp, tq), lambda i, j: (i, j)),       # w
        ],
        out_specs=pl.BlockSpec((tp, tq), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n_pre, n_post), jnp.float32),
        interpret=interpret,
    )(
        pre_spike.reshape(1, n_pre).astype(jnp.float32),
        post_spike.reshape(1, n_post).astype(jnp.float32),
        pre_words.reshape(1, n_pre).astype(jnp.uint8),
        post_words.reshape(1, n_post).astype(jnp.uint8),
        po2_ltp.reshape(1, depth).astype(jnp.float32),
        po2_ltd.reshape(1, depth).astype(jnp.float32),
        w.astype(jnp.float32),
    )
