"""Public jit'd wrapper for the fused ITP-STDP kernel.

Bridges ``repro.core`` state (SpikeHistory ring buffers, STDPParams) to the
raw Pallas kernel, padding neuron counts to lane multiples.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.history import SpikeHistory, as_register
from repro.core.stdp import STDPParams, po2_weights
from repro.kernels.itp_stdp.kernel import itp_stdp_update
from repro.kernels.itp_stdp.ref import itp_stdp_update_ref

LANE = 128


def _pad_to(x: jax.Array, n: int, axis: int) -> jax.Array:
    pad = n - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def engine_weight_update(w: jax.Array,
                         pre_spike: jax.Array, post_spike: jax.Array,
                         pre_hist: SpikeHistory, post_hist: SpikeHistory,
                         params: STDPParams,
                         *,
                         pairing: str = "nearest",
                         compensate: bool = True,
                         eta: float = 1.0,
                         w_min: float = 0.0,
                         w_max: float = 1.0,
                         use_kernel: bool = True,
                         interpret: bool = True) -> jax.Array:
    """ITP-STDP update of the full synapse matrix via the Pallas kernel.

    Drop-in accelerated replacement for ``repro.core.stdp.synapse_update``
    (same semantics, validated by tests/test_kernels.py).
    """
    n_pre, n_post = w.shape
    depth = pre_hist.depth
    po2_ltp = params.a_plus * po2_weights(depth, params.tau_plus,
                                          compensate=compensate)
    po2_ltd = params.a_minus * po2_weights(depth, params.tau_minus,
                                           compensate=compensate)
    # core stores registers (N, depth); kernel wants depth-major (depth, N)
    pre_bits = as_register(pre_hist).T
    post_bits = as_register(post_hist).T

    nearest = pairing == "nearest"
    if not use_kernel:
        return itp_stdp_update_ref(w, pre_spike, post_spike, pre_bits,
                                   post_bits, po2_ltp, po2_ltd,
                                   nearest=nearest, eta=eta,
                                   w_min=w_min, w_max=w_max)

    p_pre = _round_up(n_pre, LANE)
    p_post = _round_up(n_post, LANE)
    out = itp_stdp_update(
        _pad_to(_pad_to(w, p_pre, 0), p_post, 1),
        _pad_to(pre_spike.astype(jnp.float32), p_pre, 0),
        _pad_to(post_spike.astype(jnp.float32), p_post, 0),
        _pad_to(pre_bits, p_pre, 1),
        _pad_to(post_bits, p_post, 1),
        po2_ltp, po2_ltd,
        nearest=nearest, eta=eta, w_min=w_min, w_max=w_max,
        tile_pre=min(256, p_pre), tile_post=min(256, p_post),
        interpret=interpret,
    )
    return out[:n_pre, :n_post]
