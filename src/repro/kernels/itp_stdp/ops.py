"""Public jit'd wrappers for the fused ITP-STDP kernel.

Bridges ``repro.core`` state (SpikeHistory ring buffers, STDPParams) to the
raw Pallas kernels, padding neuron counts to lane multiples.  Two datapath
variants share one set of entry points:

  * **packed** (the storage format the fused datapath runs on): one uint8
    history word per neuron (``repro.core.history.pack_words``, the
    hardware register file), unpacked to bitplanes in-register inside the
    kernel — ``weight_update_packed`` / ``synapse_delta_packed``;
  * **unpacked bitplane** (the oracle the packed path is pinned against):
    depth-major ``(depth, N)`` float32 registers —
    ``weight_update_depth_major`` / ``engine_weight_update`` /
    ``synapse_delta``.

``interpret`` defaults to ``None`` = "derive from the host via
``repro.kernels.dispatch.default_interpret``": compiled on accelerators,
interpreter only where nothing else runs (CPU) — selecting the fused
kernel can never silently mean interpreter mode on real hardware.

``BACKENDS`` / :func:`resolve_backend` (the canonical datapath selections
shared by ``EngineConfig.backend`` / ``SNNConfig.backend``) live in
``repro.kernels.dispatch`` and are re-exported here for back-compat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.history import SpikeHistory, pack_words, registers_depth_major
from repro.core.stdp import STDPParams, po2_weights
from repro.kernels.dispatch import BACKENDS, LANE, resolve_backend  # noqa: F401 (re-export)
from repro.kernels.dispatch import default_interpret, resolve_packed
from repro.kernels.dispatch import pad_axis as _pad_to
from repro.kernels.dispatch import round_up as _round_up
from repro.kernels.itp_stdp.kernel import (itp_stdp_update,
                                           itp_stdp_update_packed)
from repro.kernels.itp_stdp.ref import itp_stdp_update_ref


def _tile(padded: int) -> int:
    """Largest of (256, LANE) that divides the padded (LANE-multiple) dim."""
    return 256 if padded % 256 == 0 else LANE


def _resolve_interpret(interpret: bool | None) -> bool:
    return default_interpret() if interpret is None else interpret


def weight_update_depth_major(w: jax.Array,
                              pre_spike: jax.Array, post_spike: jax.Array,
                              pre_bits: jax.Array, post_bits: jax.Array,
                              params: STDPParams,
                              *,
                              pairing: str = "nearest",
                              compensate: bool = True,
                              eta: float = 1.0,
                              w_min: float = 0.0,
                              w_max: float = 1.0,
                              use_kernel: bool = True,
                              interpret: bool | None = None) -> jax.Array:
    """Fused ITP-STDP update from depth-major ``(depth, N)`` registers.

    ``pre_bits``/``post_bits`` are the logical registers with the k=0 row
    most recent (``repro.core.history.registers_depth_major``) — the layout
    the kernel consumes with no relayout.  Semantics match
    ``repro.core.stdp.synapse_update`` (validated by tests/test_kernels.py
    and tests/test_backend.py).
    """
    n_pre, n_post = w.shape
    depth = pre_bits.shape[0]
    po2_ltp = params.a_plus * po2_weights(depth, params.tau_plus,
                                          compensate=compensate)
    po2_ltd = params.a_minus * po2_weights(depth, params.tau_minus,
                                           compensate=compensate)
    nearest = pairing == "nearest"
    if not use_kernel:
        return itp_stdp_update_ref(w, pre_spike, post_spike, pre_bits,
                                   post_bits, po2_ltp, po2_ltd,
                                   nearest=nearest, eta=eta,
                                   w_min=w_min, w_max=w_max)

    p_pre = _round_up(n_pre, LANE)
    p_post = _round_up(n_post, LANE)
    out = itp_stdp_update(
        _pad_to(_pad_to(w, p_pre, 0), p_post, 1),
        _pad_to(pre_spike.astype(jnp.float32), p_pre, 0),
        _pad_to(post_spike.astype(jnp.float32), p_post, 0),
        _pad_to(pre_bits.astype(jnp.float32), p_pre, 1),
        _pad_to(post_bits.astype(jnp.float32), p_post, 1),
        po2_ltp, po2_ltd,
        nearest=nearest, eta=eta, w_min=w_min, w_max=w_max,
        tile_pre=_tile(p_pre), tile_post=_tile(p_post),
        interpret=_resolve_interpret(interpret),
    )
    return out[:n_pre, :n_post]


def weight_update_packed(w: jax.Array,
                         pre_spike: jax.Array, post_spike: jax.Array,
                         pre_words: jax.Array, post_words: jax.Array,
                         params: STDPParams,
                         *,
                         depth: int,
                         pairing: str = "nearest",
                         compensate: bool = True,
                         eta: float = 1.0,
                         w_min: float = 0.0,
                         w_max: float = 1.0,
                         use_kernel: bool = True,
                         interpret: bool | None = None) -> jax.Array:
    """Fused ITP-STDP update from packed uint8 history words.

    ``pre_words``/``post_words`` are one ``uint8`` register word per neuron
    (``repro.core.history.pack_words``, MSB = most recent) — the paper's
    8-bit register file read in place.  Zero padding is exact: a zero word
    carries no history bits, so padded neurons contribute nothing.
    Bit-identical to :func:`weight_update_depth_major` on the kernel path
    (shared fused body) and pinned against it by tests/test_kernels.py.
    """
    n_pre, n_post = w.shape
    po2_ltp = params.a_plus * po2_weights(depth, params.tau_plus,
                                          compensate=compensate)
    po2_ltd = params.a_minus * po2_weights(depth, params.tau_minus,
                                           compensate=compensate)
    nearest = pairing == "nearest"
    if not use_kernel:
        from repro.core.history import unpack_words
        return itp_stdp_update_ref(
            w, pre_spike, post_spike,
            unpack_words(pre_words, depth).T, unpack_words(post_words, depth).T,
            po2_ltp, po2_ltd, nearest=nearest, eta=eta,
            w_min=w_min, w_max=w_max)

    p_pre = _round_up(n_pre, LANE)
    p_post = _round_up(n_post, LANE)
    out = itp_stdp_update_packed(
        _pad_to(_pad_to(w, p_pre, 0), p_post, 1),
        _pad_to(pre_spike.astype(jnp.float32), p_pre, 0),
        _pad_to(post_spike.astype(jnp.float32), p_post, 0),
        _pad_to(pre_words.astype(jnp.uint8), p_pre, 0),
        _pad_to(post_words.astype(jnp.uint8), p_post, 0),
        po2_ltp, po2_ltd,
        depth=depth, nearest=nearest, eta=eta, w_min=w_min, w_max=w_max,
        tile_pre=_tile(p_pre), tile_post=_tile(p_post),
        interpret=_resolve_interpret(interpret),
    )
    return out[:n_pre, :n_post]


def engine_weight_update(w: jax.Array,
                         pre_spike: jax.Array, post_spike: jax.Array,
                         pre_hist: SpikeHistory, post_hist: SpikeHistory,
                         params: STDPParams,
                         *,
                         pairing: str = "nearest",
                         compensate: bool = True,
                         eta: float = 1.0,
                         w_min: float = 0.0,
                         w_max: float = 1.0,
                         use_kernel: bool = True,
                         packed: bool = True,
                         interpret: bool | None = None) -> jax.Array:
    """ITP-STDP update of the full synapse matrix via the Pallas kernel.

    Drop-in accelerated replacement for ``repro.core.stdp.synapse_update``
    (same semantics, validated by tests/test_kernels.py).  ``packed=True``
    (the default) feeds the kernel one uint8 word per neuron; ``False``
    keeps the unpacked bitplane operands (the oracle datapath).  The
    routing itself is owned by ``dispatch.resolve_packed`` — this wrapper
    carries no selection logic of its own.
    """
    if resolve_packed(packed, depth=pre_hist.depth, use_kernel=use_kernel):
        return weight_update_packed(
            w, pre_spike, post_spike,
            pack_words(pre_hist), pack_words(post_hist), params,
            depth=pre_hist.depth, pairing=pairing, compensate=compensate,
            eta=eta, w_min=w_min, w_max=w_max, use_kernel=use_kernel,
            interpret=interpret)
    return weight_update_depth_major(
        w, pre_spike, post_spike,
        registers_depth_major(pre_hist), registers_depth_major(post_hist),
        params, pairing=pairing, compensate=compensate, eta=eta,
        w_min=w_min, w_max=w_max, use_kernel=use_kernel, interpret=interpret)


def synapse_delta(pre_spike: jax.Array, post_spike: jax.Array,
                  pre_bits: jax.Array, post_bits: jax.Array,
                  params: STDPParams,
                  *,
                  pairing: str = "nearest",
                  compensate: bool = True,
                  use_kernel: bool = True,
                  interpret: bool | None = None) -> jax.Array:
    """Raw Δw (pre × post) from depth-major registers — no clip, no ``w``.

    Batched callers (the SNN fc layers, population training) vmap this over
    replicas/batch, accumulate, and apply clip/quantise once — bit-identical
    to the reference einsum path because the kernel's gated outer product is
    linear in the gate terms.  Reuses the fused kernel with a zero weight
    tile and an unbounded clip window.
    """
    n_pre = pre_bits.shape[1]
    n_post = post_bits.shape[1]
    zero_w = jnp.zeros((n_pre, n_post), jnp.float32)
    return weight_update_depth_major(
        zero_w, pre_spike, post_spike, pre_bits, post_bits, params,
        pairing=pairing, compensate=compensate, eta=1.0,
        w_min=float("-inf"), w_max=float("inf"),
        use_kernel=use_kernel, interpret=interpret)


def synapse_delta_packed(pre_spike: jax.Array, post_spike: jax.Array,
                         pre_words: jax.Array, post_words: jax.Array,
                         params: STDPParams,
                         *,
                         depth: int,
                         pairing: str = "nearest",
                         compensate: bool = True,
                         use_kernel: bool = True,
                         interpret: bool | None = None) -> jax.Array:
    """Raw Δw (pre × post) from packed uint8 history words.

    The packed twin of :func:`synapse_delta`: same zero-weight /
    unbounded-clip trick, but the history operands are one byte per neuron
    instead of ``4·depth`` — the SNN fc layers' fused batch path.
    """
    n_pre = pre_words.shape[-1]
    n_post = post_words.shape[-1]
    zero_w = jnp.zeros((n_pre, n_post), jnp.float32)
    return weight_update_packed(
        zero_w, pre_spike, post_spike, pre_words, post_words, params,
        depth=depth, pairing=pairing, compensate=compensate, eta=1.0,
        w_min=float("-inf"), w_max=float("inf"),
        use_kernel=use_kernel, interpret=interpret)
