"""Public jit'd wrappers for the fused ITP-STDP kernel.

Bridges ``repro.core`` state (SpikeHistory ring buffers, STDPParams) to the
raw Pallas kernel, padding neuron counts to lane multiples.  Three entry
points, from lowest to highest level:

  * :func:`weight_update_depth_major` — fused update from depth-major
    ``(depth, N)`` bitplane registers (the engine/sharded hot-path layout);
  * :func:`engine_weight_update`      — same, from ``SpikeHistory`` state;
  * :func:`synapse_delta`             — Δw only (no clip, no ``w`` read),
    for batched callers that accumulate over replicas before applying.

``BACKENDS`` / :func:`resolve_backend` (the canonical datapath selections
shared by ``EngineConfig.backend`` / ``SNNConfig.backend``) live in
``repro.kernels.dispatch`` and are re-exported here for back-compat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.history import SpikeHistory, registers_depth_major
from repro.core.stdp import STDPParams, po2_weights
from repro.kernels.dispatch import BACKENDS, LANE, resolve_backend  # noqa: F401 (re-export)
from repro.kernels.dispatch import pad_axis as _pad_to
from repro.kernels.dispatch import round_up as _round_up
from repro.kernels.itp_stdp.kernel import itp_stdp_update
from repro.kernels.itp_stdp.ref import itp_stdp_update_ref


def _tile(padded: int) -> int:
    """Largest of (256, LANE) that divides the padded (LANE-multiple) dim."""
    return 256 if padded % 256 == 0 else LANE


def weight_update_depth_major(w: jax.Array,
                              pre_spike: jax.Array, post_spike: jax.Array,
                              pre_bits: jax.Array, post_bits: jax.Array,
                              params: STDPParams,
                              *,
                              pairing: str = "nearest",
                              compensate: bool = True,
                              eta: float = 1.0,
                              w_min: float = 0.0,
                              w_max: float = 1.0,
                              use_kernel: bool = True,
                              interpret: bool = True) -> jax.Array:
    """Fused ITP-STDP update from depth-major ``(depth, N)`` registers.

    ``pre_bits``/``post_bits`` are the logical registers with the k=0 row
    most recent (``repro.core.history.registers_depth_major``) — the layout
    the kernel consumes with no relayout.  Semantics match
    ``repro.core.stdp.synapse_update`` (validated by tests/test_kernels.py
    and tests/test_backend.py).
    """
    n_pre, n_post = w.shape
    depth = pre_bits.shape[0]
    po2_ltp = params.a_plus * po2_weights(depth, params.tau_plus,
                                          compensate=compensate)
    po2_ltd = params.a_minus * po2_weights(depth, params.tau_minus,
                                           compensate=compensate)
    nearest = pairing == "nearest"
    if not use_kernel:
        return itp_stdp_update_ref(w, pre_spike, post_spike, pre_bits,
                                   post_bits, po2_ltp, po2_ltd,
                                   nearest=nearest, eta=eta,
                                   w_min=w_min, w_max=w_max)

    p_pre = _round_up(n_pre, LANE)
    p_post = _round_up(n_post, LANE)
    out = itp_stdp_update(
        _pad_to(_pad_to(w, p_pre, 0), p_post, 1),
        _pad_to(pre_spike.astype(jnp.float32), p_pre, 0),
        _pad_to(post_spike.astype(jnp.float32), p_post, 0),
        _pad_to(pre_bits.astype(jnp.float32), p_pre, 1),
        _pad_to(post_bits.astype(jnp.float32), p_post, 1),
        po2_ltp, po2_ltd,
        nearest=nearest, eta=eta, w_min=w_min, w_max=w_max,
        tile_pre=_tile(p_pre), tile_post=_tile(p_post),
        interpret=interpret,
    )
    return out[:n_pre, :n_post]


def engine_weight_update(w: jax.Array,
                         pre_spike: jax.Array, post_spike: jax.Array,
                         pre_hist: SpikeHistory, post_hist: SpikeHistory,
                         params: STDPParams,
                         *,
                         pairing: str = "nearest",
                         compensate: bool = True,
                         eta: float = 1.0,
                         w_min: float = 0.0,
                         w_max: float = 1.0,
                         use_kernel: bool = True,
                         interpret: bool = True) -> jax.Array:
    """ITP-STDP update of the full synapse matrix via the Pallas kernel.

    Drop-in accelerated replacement for ``repro.core.stdp.synapse_update``
    (same semantics, validated by tests/test_kernels.py).
    """
    return weight_update_depth_major(
        w, pre_spike, post_spike,
        registers_depth_major(pre_hist), registers_depth_major(post_hist),
        params, pairing=pairing, compensate=compensate, eta=eta,
        w_min=w_min, w_max=w_max, use_kernel=use_kernel, interpret=interpret)


def synapse_delta(pre_spike: jax.Array, post_spike: jax.Array,
                  pre_bits: jax.Array, post_bits: jax.Array,
                  params: STDPParams,
                  *,
                  pairing: str = "nearest",
                  compensate: bool = True,
                  use_kernel: bool = True,
                  interpret: bool = True) -> jax.Array:
    """Raw Δw (pre × post) from depth-major registers — no clip, no ``w``.

    Batched callers (the SNN fc layers, population training) vmap this over
    replicas/batch, accumulate, and apply clip/quantise once — bit-identical
    to the reference einsum path because the kernel's gated outer product is
    linear in the gate terms.  Reuses the fused kernel with a zero weight
    tile and an unbounded clip window.
    """
    n_pre = pre_bits.shape[1]
    n_post = post_bits.shape[1]
    zero_w = jnp.zeros((n_pre, n_post), jnp.float32)
    return weight_update_depth_major(
        zero_w, pre_spike, post_spike, pre_bits, post_bits, params,
        pairing=pairing, compensate=compensate, eta=1.0,
        w_min=float("-inf"), w_max=float("inf"),
        use_kernel=use_kernel, interpret=interpret)
