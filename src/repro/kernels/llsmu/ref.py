"""Oracle for the LLSMu kernel — delegates to the core fixed-point model."""
from __future__ import annotations

import jax

from repro.core.llsmu import llsmu_fixed


def llsmu_multiply_ref(a: jax.Array, b: jax.Array, *, n_bits: int = 4,
                       frac_bits: int = 12, c: float = 0.08333) -> jax.Array:
    return llsmu_fixed(a, b, n_bits=n_bits, frac_bits=frac_bits, c=c)
