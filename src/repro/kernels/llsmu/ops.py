"""Jit'd wrapper for the LLSMu kernel: arbitrary shapes, signed operands."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.dispatch import default_interpret
from repro.kernels.llsmu.kernel import llsmu_multiply
from repro.kernels.llsmu.ref import llsmu_multiply_ref

LANE = 128


def llsmu(a: jax.Array, b: jax.Array, *, n_bits: int = 4,
          frac_bits: int = 12, c: float = 0.08333,
          use_kernel: bool = True,
          interpret: bool | None = None) -> jax.Array:
    """Signed LLSMu approximate multiply, any (broadcastable-equal) shape.

    ``interpret=None`` resolves via ``dispatch.default_interpret`` (R3).
    """
    if interpret is None:
        interpret = default_interpret()
    a = jnp.asarray(a, jnp.int32)
    b = jnp.asarray(b, jnp.int32)
    if a.shape != b.shape:
        a, b = jnp.broadcast_arrays(a, b)
    sign = jnp.sign(a) * jnp.sign(b)
    aa, bb = jnp.abs(a), jnp.abs(b)
    if not use_kernel:
        return sign * llsmu_multiply_ref(aa, bb, n_bits=n_bits,
                                         frac_bits=frac_bits, c=c)
    shape = aa.shape
    flat_a = aa.reshape(-1)
    flat_b = bb.reshape(-1)
    n = flat_a.shape[0]
    pad = (-n) % LANE
    if pad:
        flat_a = jnp.pad(flat_a, (0, pad))
        flat_b = jnp.pad(flat_b, (0, pad))
    out = llsmu_multiply(flat_a, flat_b, n_bits=n_bits, frac_bits=frac_bits,
                         c=c, tile=LANE, interpret=interpret)
    return sign * out[:n].reshape(shape)
