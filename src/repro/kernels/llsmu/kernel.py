"""LLSMu approximate-multiplier Pallas kernel (paper §II-D, eqs. 6-14).

Elementwise integer kernel: Karatsuba split + three Mitchell log-multiplies
+ exact recombination, on int32 tiles.  Every operation is a VPU-native
shift/compare/add — the TPU rendering of the multiplier-free datapath the
paper builds in LUTs.  The leading-one detector (the FPGA priority encoder
of Fig. 9's preprocessing module) becomes a threshold-compare reduction,
unrolled over the operand width.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _floor_log2(x: jax.Array, max_bits: int) -> jax.Array:
    """k = ⌊log2 x⌋ via an unrolled threshold-compare chain (exact)."""
    k = jnp.zeros_like(x)
    for i in range(1, max_bits):
        k = k + (x >= (1 << i)).astype(jnp.int32)
    return k


def _var_shift(mant: jax.Array, s: jax.Array) -> jax.Array:
    left = jnp.maximum(s, 0)
    right = jnp.maximum(-s, 0)
    return (mant << left) >> right


def _mitchell(x: jax.Array, y: jax.Array, *, frac_bits: int, cq: int,
              max_bits: int) -> jax.Array:
    one = 1 << frac_bits
    kx = _floor_log2(x, max_bits)
    ky = _floor_log2(y, max_bits)
    fx = _var_shift(x, frac_bits - kx)
    fy = _var_shift(y, frac_bits - ky)
    delta = fx + fy - 2 * one
    mant = jnp.where(delta < one, one + delta + cq, 2 * (delta + cq // 2))
    p = _var_shift(mant, kx + ky - frac_bits)
    return jnp.where((x == 0) | (y == 0), 0, p)


def _llsmu_kernel(a_ref, b_ref, o_ref, *, n_bits: int, frac_bits: int,
                  cq: int, max_bits: int):
    a = a_ref[...]
    b = b_ref[...]
    mask = (1 << n_bits) - 1
    ha, la = a >> n_bits, a & mask
    hb, lb = b >> n_bits, b & mask
    m = functools.partial(_mitchell, frac_bits=frac_bits, cq=cq,
                          max_bits=max_bits)
    m0 = m(la, lb)
    m1 = m(ha, hb)
    m2 = m(ha + la, hb + lb)
    s3 = m2 - m0 - m1
    o_ref[...] = (m1 << (2 * n_bits)) + (s3 << n_bits) + m0


@functools.partial(
    jax.jit,
    static_argnames=("n_bits", "frac_bits", "c", "tile", "interpret"),
)
def llsmu_multiply(a: jax.Array, b: jax.Array, *,
                   n_bits: int = 4, frac_bits: int = 12,
                   c: float = 0.08333, tile: int = 512,
                   interpret: bool = True) -> jax.Array:
    """Elementwise LLSMu approximate multiply of flat int32 arrays.

    Operands must be non-negative; callers handle sign (sign-magnitude, as
    in the hardware).  Shapes: both (n,) → (n,).
    """
    (n,) = a.shape
    t = min(tile, n)
    if n % t:
        raise ValueError(f"tile {t} must divide length {n}")
    cq = int(round(c * (1 << frac_bits)))
    max_bits = 2 * n_bits + 2  # operands ≤ 2N+1 bits after the Karatsuba add
    kern = functools.partial(_llsmu_kernel, n_bits=n_bits,
                             frac_bits=frac_bits, cq=cq, max_bits=max_bits + 8)
    return pl.pallas_call(
        kern,
        grid=(n // t,),
        in_specs=[
            pl.BlockSpec((1, t), lambda i: (0, i)),
            pl.BlockSpec((1, t), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((1, t), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, n), jnp.int32),
        interpret=interpret,
    )(a.reshape(1, n).astype(jnp.int32), b.reshape(1, n).astype(jnp.int32))[0]
