"""Shared backend dispatch + padding helpers for every kernel package.

``BACKENDS`` is the canonical set of weight-update datapath selections
understood across the whole stack (engine, sharded engine, SNN models,
launcher, benchmarks):

  * ``reference``       — pure-jnp path (``repro.core`` / the ``ref.py``
                          oracle of each kernel package)
  * ``fused``           — Pallas kernel compiled for the accelerator
  * ``fused_interpret`` — the same kernel via the interpreter (CPU
                          validation; jitted, so still fast)
  * ``sparse``          — event-driven datapath (``repro.kernels.
                          itp_sparse``): static-shape event lists gate
                          gather/scatter updates of only the touched
                          weight slices, instead of the dense n_pre ×
                          n_post tile the other backends read

:func:`resolve_backend` maps a name to the ``(use_kernel, interpret)``
pair the per-package ``ops.py`` wrappers take; ``sparse`` is *not* a
Pallas path, so it maps to ``(False, False)`` and consumers branch on
the backend name explicitly.  The lane/tile padding helpers live here
too so each kernel package stops re-deriving them.

This module is also the only sanctioned surface through which code
*outside* ``repro.kernels`` / ``repro.plasticity`` touches the
``repro.kernels.itp_*`` packages (lint rule R2, ``tools/check.py``):
the rule-neutral helpers those packages export — the static-shape
event-list primitives of ``itp_sparse.events`` and the im2col layout
helpers of ``itp_stdp_conv.ops`` — re-export here lazily (PEP 562
``__getattr__``, so importing ``dispatch`` from inside a kernel package
never cycles), and the engines/models import *this* module instead of
reaching into a kernel package directly.
"""
from __future__ import annotations

import importlib

import jax
import jax.numpy as jnp

# name → defining module for the sanctioned kernel-package re-exports;
# resolved lazily on first attribute access and cached in globals()
_KERNEL_REEXPORTS = {
    "event_cap": "repro.kernels.itp_sparse.events",
    "spike_events": "repro.kernels.itp_sparse.events",
    "word_events": "repro.kernels.itp_sparse.events",
    "im2col_1d": "repro.kernels.itp_stdp_conv.ops",
    "im2col_2d": "repro.kernels.itp_stdp_conv.ops",
    "im2col_words_1d": "repro.kernels.itp_stdp_conv.ops",
    "im2col_words_2d": "repro.kernels.itp_stdp_conv.ops",
}


def __getattr__(name: str):
    target = _KERNEL_REEXPORTS.get(name)
    if target is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    value = getattr(importlib.import_module(target), name)
    globals()[name] = value  # cache: later lookups skip __getattr__
    return value


LANE = 128
SUBLANE = 8

BACKENDS = ("reference", "fused", "fused_interpret", "sparse")


def resolve_backend(backend: str) -> tuple[bool, bool]:
    """Map a backend name to the ``(use_kernel, interpret)`` pair."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; have {BACKENDS}")
    if backend == "sparse":
        return False, False
    return backend != "reference", backend == "fused_interpret"


def resolve_packed(packed_history: bool, *, depth: int,
                   use_kernel: bool = True) -> bool:
    """Single owner of the packed-vs-unpacked operand selection.

    The packed layout is the paper's 8-bit register file — one uint8
    word per neuron — so it can only hold ``depth <= 8``; deeper
    histories keep the unpacked bitplane operands (packing is purely a
    bandwidth optimisation, bit-identical where available, so the
    fallback is silent rather than an error).  Ops wrappers additionally
    pass ``use_kernel`` so the reference oracle always reads the
    unpacked registers it is defined on.  ``EngineConfig`` /
    ``SNNConfig.use_packed_history()`` and the ``itp_stdp`` engine
    wrapper all resolve through here — no call site re-derives the
    routing.
    """
    return bool(packed_history) and use_kernel and depth <= 8


def default_fused_backend() -> str:
    """The fused backend this host can actually run.

    CPU can only run the Pallas kernels through the interpreter
    (``fused_interpret``); on an accelerator the compiled kernel
    (``fused``) is the only sane default — a caller selecting the fused
    path must never silently get interpreter mode on real hardware.
    """
    return "fused_interpret" if jax.default_backend() == "cpu" else "fused"


def default_interpret() -> bool:
    """Default ``interpret`` flag for ops wrappers when the caller does not
    thread one: resolved from :func:`default_fused_backend`, so
    ``backend="fused"`` semantics (compiled) apply on every accelerator and
    interpreter mode is chosen only where nothing else can run (CPU)."""
    return resolve_backend(default_fused_backend())[1]


def round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


def pad_axis(x: jax.Array, n: int, axis: int) -> jax.Array:
    """Zero-pad ``x`` along ``axis`` up to length ``n`` (no-op if equal)."""
    pad = n - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)
