"""Pure-jnp oracle for the fused LIF kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lif_update_ref(v: jax.Array, i_in: jax.Array, *,
                   alpha: float, e_rest: float = 0.0,
                   v_th: float = 1.0) -> tuple[jax.Array, jax.Array]:
    v = v.astype(jnp.float32)
    v_new = alpha * (v - e_rest) + e_rest + i_in.astype(jnp.float32)
    spikes = v_new > v_th
    return jnp.where(spikes, e_rest, v_new), spikes.astype(jnp.float32)
