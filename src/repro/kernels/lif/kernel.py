"""Fused LIF neuron-update Pallas kernel (paper eqs. 4-5).

One VMEM round trip per (batch, neuron) tile for the whole
integrate → compare → fire → reset sequence:

    v' = α·(v − E) + E + I ;  s = v' > V_th ;  v'' = s ? E : v'

The FPGA version pipelines this over 8 stages to time-multiplex one
arithmetic unit over 8 neurons; on TPU the same locality argument says
"keep v in VREGs across all four sub-steps", which the fused kernel
guarantees and a composed jnp implementation does not (XLA usually fuses
this too — the kernel makes the contract explicit and is the unit we
block-sweep in tests).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _lif_kernel(v_ref, i_ref, v_out_ref, s_out_ref, *,
                alpha: float, e_rest: float, v_th: float):
    v = v_ref[...]
    v_new = alpha * (v - e_rest) + e_rest + i_ref[...]
    spikes = v_new > v_th
    v_out_ref[...] = jnp.where(spikes, e_rest, v_new)
    s_out_ref[...] = spikes.astype(jnp.float32)


@functools.partial(
    jax.jit,
    static_argnames=("alpha", "e_rest", "v_th", "tile_b", "tile_n", "interpret"),
)
def lif_update(v: jax.Array, i_in: jax.Array, *,
               alpha: float, e_rest: float = 0.0, v_th: float = 1.0,
               tile_b: int = 8, tile_n: int = 512,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Fused LIF step over a (batch, n_neurons) state tile.

    Returns ``(v_next, spikes)`` with spikes as float32 {0,1}.
    """
    b, n = v.shape
    tb = min(tile_b, b)
    tn = min(tile_n, n)
    if b % tb or n % tn:
        raise ValueError(f"tiles ({tb},{tn}) must divide state shape ({b},{n})")
    kern = functools.partial(_lif_kernel, alpha=alpha, e_rest=e_rest, v_th=v_th)
    return pl.pallas_call(
        kern,
        grid=(b // tb, n // tn),
        in_specs=[
            pl.BlockSpec((tb, tn), lambda i, j: (i, j)),
            pl.BlockSpec((tb, tn), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((tb, tn), lambda i, j: (i, j)),
            pl.BlockSpec((tb, tn), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, n), jnp.float32),
            jax.ShapeDtypeStruct((b, n), jnp.float32),
        ],
        interpret=interpret,
    )(v.astype(jnp.float32), i_in.astype(jnp.float32))
