"""Jit'd wrapper for the fused LIF kernel with core-API adapters."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.lif import LIFParams, LIFState
from repro.kernels.dispatch import LANE, default_interpret
from repro.kernels.dispatch import round_up as _round_up
from repro.kernels.lif.kernel import lif_update
from repro.kernels.lif.ref import lif_update_ref


def lif_step_kernel(state: LIFState, i_in: jax.Array, p: LIFParams,
                    *, use_kernel: bool = True,
                    interpret: bool | None = None
                    ) -> tuple[LIFState, jax.Array]:
    """Kernel-backed drop-in for ``repro.core.lif.lif_step``.

    Accepts 1-D (n,) or 2-D (batch, n) membrane state; pads the neuron axis
    to a lane multiple for the TPU layout.  ``interpret=None`` resolves via
    ``dispatch.default_interpret`` (lint rule R3: ops wrappers must not bake
    a literal interpreter default that ignores the host).
    """
    if interpret is None:
        interpret = default_interpret()
    v = state.v
    squeeze = v.ndim == 1
    if squeeze:
        v = v[None, :]
        i_in = i_in[None, :]
    if not use_kernel:
        v2, s = lif_update_ref(v, i_in, alpha=p.alpha, e_rest=p.e_rest,
                               v_th=p.v_th)
    else:
        b, n = v.shape
        np_ = _round_up(n, LANE)
        bp_ = _round_up(b, 8) if b > 1 else 1
        vp = jnp.pad(v, ((0, bp_ - b), (0, np_ - n)))
        ip = jnp.pad(i_in, ((0, bp_ - b), (0, np_ - n)))
        v2, s = lif_update(vp, ip, alpha=p.alpha, e_rest=p.e_rest,
                           v_th=p.v_th, tile_b=min(8, bp_),
                           tile_n=min(512, np_), interpret=interpret)
        v2, s = v2[:b, :n], s[:b, :n]
    if squeeze:
        v2, s = v2[0], s[0]
    return LIFState(v=v2), s.astype(jnp.bool_)
