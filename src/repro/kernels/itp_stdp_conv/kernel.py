"""Fused im2col ITP-STDP conv-update Pallas kernel.

The conv layers apply the pair-based STDP rule per (patch element ->
output channel) synapse, accumulated over batch and spatial positions
(src/repro/models/snn.py).  After im2col the whole update collapses to

    dw[k, c] = sum_m (1 - pre[m, k]) * ltp_mag[m, k] * post[m, c]
             - sum_m pre[m, k] * (1 - post[m, c]) * ltd_mag[m, c]

where m runs over the M = batch x positions patch rows and the LTP/LTD
magnitudes are the po2 reads of the spike-history bitplanes — two MXU
matmuls contracting the large M axis, fused with the history read and the
pair gating in one pass.

Layout choices (HW-codesign reasoning, mirroring the dense itp_stdp
kernel):
  * the patch rows M sit on the grid + sublane axis; the small patch
    width K and channel count C are padded to the 128-lane boundary by
    ops.py, so both matmuls are MXU-aligned;
  * bitplanes arrive depth-major (depth, TM, K): the po2 read is a
    length-depth reduction over the leading axis, kept entirely in VREGs;
  * the (K, C) delta tile stays resident in VMEM across the whole grid —
    each grid step accumulates its tile's two dot products into it, so the
    weight delta makes exactly one HBM round-trip.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _unpack_bits(words: jax.Array, depth: int) -> jax.Array:
    """In-register bitplane unpack: (TM, X) uint8 words → (depth, TM, X) f32.

    Shift+mask per depth slot (paper eq. 2 / Fig. 3): bit k of the logical
    register sits at word bit ``7 - k`` (MSB = most recent,
    ``repro.core.history.pack_words``).  The bitplanes never touch HBM —
    only the one byte per patch element does.
    """
    w = words.astype(jnp.int32)
    planes = [((w >> (7 - k)) & 1)[None] for k in range(depth)]
    return jnp.concatenate(planes, axis=0).astype(jnp.float32)


def _conv_stdp_body(
    pre, post, pre_bits, post_bits, po2_ltp_ref, po2_ltd_ref, out_ref, *, nearest: bool
):
    """Shared fused conv datapath: po2 read → pair gate → two MXU matmuls.

    Both kernel variants (bitplane-fed and packed-word-fed) route through
    this body, so the packed path is bit-identical to the unpacked one by
    construction.
    """
    if nearest:
        # Fig. 11 MSB mask: keep only the first '1' scanning most-recent-first
        pre_bits = pre_bits * (jnp.cumsum(pre_bits, axis=0) == 1.0)
        post_bits = post_bits * (jnp.cumsum(post_bits, axis=0) == 1.0)

    # po2 read: reduce the depth axis against the place-value vector — the
    # 'register read IS the weight update' step, per patch element
    depth = pre_bits.shape[0]
    po2_ltp = po2_ltp_ref[...].reshape(depth, 1, 1)
    po2_ltd = po2_ltd_ref[...].reshape(depth, 1, 1)
    ltp_mag = jnp.sum(po2_ltp * pre_bits, axis=0)  # (TM, K)
    ltd_mag = jnp.sum(po2_ltd * post_bits, axis=0)  # (TM, C)

    # XOR/AND pair gate: potentiate where post fired alone, depress where
    # pre fired alone; contract the patch-row axis on the MXU
    contract = (((0,), (0,)), ((), ()))
    ltp_term = (1.0 - pre) * ltp_mag  # (TM, K)
    ltd_term = (1.0 - post) * ltd_mag  # (TM, C)
    dw_ltp = jax.lax.dot_general(ltp_term, post, contract, preferred_element_type=jnp.float32)
    dw_ltd = jax.lax.dot_general(pre, ltd_term, contract, preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    out_ref[...] += dw_ltp - dw_ltd


def _conv_stdp_kernel(
    pre_ref,
    post_ref,
    pre_bits_ref,
    post_bits_ref,
    po2_ltp_ref,
    po2_ltd_ref,
    out_ref,
    *,
    nearest: bool,
):
    pre = pre_ref[...].astype(jnp.float32)  # (TM, K)
    post = post_ref[...].astype(jnp.float32)  # (TM, C)
    pre_bits = pre_bits_ref[...].astype(jnp.float32)  # (depth, TM, K)
    post_bits = post_bits_ref[...].astype(jnp.float32)  # (depth, TM, C)
    _conv_stdp_body(
        pre, post, pre_bits, post_bits, po2_ltp_ref, po2_ltd_ref, out_ref, nearest=nearest
    )


def _conv_stdp_packed_kernel(
    pre_ref,
    post_ref,
    pre_word_ref,
    post_word_ref,
    po2_ltp_ref,
    po2_ltd_ref,
    out_ref,
    *,
    depth: int,
    nearest: bool,
):
    pre = pre_ref[...].astype(jnp.float32)  # (TM, K)
    post = post_ref[...].astype(jnp.float32)  # (TM, C)
    # (TM, K) / (TM, C) packed uint8 words — one byte per patch element
    # crosses HBM; the (depth, TM, ·) bitplanes exist only in-register
    pre_bits = _unpack_bits(pre_word_ref[...], depth)
    post_bits = _unpack_bits(post_word_ref[...], depth)
    _conv_stdp_body(
        pre, post, pre_bits, post_bits, po2_ltp_ref, po2_ltd_ref, out_ref, nearest=nearest
    )


@functools.partial(
    jax.jit,
    static_argnames=("nearest", "tile_m", "interpret"),
)
def itp_stdp_conv_delta(
    pre_patches: jax.Array,
    post_spikes: jax.Array,
    pre_bits: jax.Array,
    post_bits: jax.Array,
    po2_ltp: jax.Array,
    po2_ltd: jax.Array,
    *,
    nearest: bool = True,
    tile_m: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Patch-level fused ITP-STDP conv weight delta.

    Args:
      pre_patches: (M, K) im2col spike patches, M = batch x output positions.
      post_spikes: (M, C) current-step output spikes.
      pre_bits:    (depth, M, K) bitplane patches, k=0 row most recent.
      post_bits:   (depth, M, C) output bitplanes.
      po2_ltp:     (depth,) LTP read vector (A+ amplitude folded in).
      po2_ltd:     (depth,) LTD read vector (A- amplitude folded in).
      nearest:     nearest-neighbour (True) or all-to-all (False) pairing.
      tile_m:      patch rows per grid step; must divide M.
      interpret:   run through the Pallas interpreter (CPU validation);
                   the default False targets real accelerator hardware.

    Returns the (K, C) float32 delta accumulated over all M patch rows.
    """
    m, kk = pre_patches.shape
    cc = post_spikes.shape[1]
    depth = pre_bits.shape[0]
    tm = min(tile_m, m)
    if m % tm:
        raise ValueError(f"tile_m={tm} must divide M={m}")

    kern = functools.partial(_conv_stdp_kernel, nearest=nearest)
    return pl.pallas_call(
        kern,
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((tm, kk), lambda i: (i, 0)),  # pre patches
            pl.BlockSpec((tm, cc), lambda i: (i, 0)),  # post spikes
            pl.BlockSpec((depth, tm, kk), lambda i: (0, i, 0)),  # pre bitplanes
            pl.BlockSpec((depth, tm, cc), lambda i: (0, i, 0)),  # post bitplanes
            pl.BlockSpec((1, depth), lambda i: (0, 0)),  # po2 LTP read vector
            pl.BlockSpec((1, depth), lambda i: (0, 0)),  # po2 LTD read vector
        ],
        out_specs=pl.BlockSpec((kk, cc), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((kk, cc), jnp.float32),
        interpret=interpret,
    )(
        pre_patches.astype(jnp.float32),
        post_spikes.astype(jnp.float32),
        pre_bits.astype(jnp.float32),
        post_bits.astype(jnp.float32),
        po2_ltp.reshape(1, depth).astype(jnp.float32),
        po2_ltd.reshape(1, depth).astype(jnp.float32),
    )


@functools.partial(
    jax.jit,
    static_argnames=("depth", "nearest", "tile_m", "interpret"),
)
def itp_stdp_conv_delta_packed(
    pre_patches: jax.Array,
    post_spikes: jax.Array,
    pre_words: jax.Array,
    post_words: jax.Array,
    po2_ltp: jax.Array,
    po2_ltd: jax.Array,
    *,
    depth: int,
    nearest: bool = True,
    tile_m: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """Patch-level fused conv delta fed by packed uint8 history words.

    The storage-format variant of :func:`itp_stdp_conv_delta`: the history
    operands are one uint8 register word per patch element / output neuron
    (``repro.core.history.pack_words``, MSB = most recent) instead of
    ``(depth, M, ·)`` float32 bitplanes — a ``4·depth``× reduction of the
    dominant HBM stream.  Bitplanes are unpacked in-register (shift+mask
    per depth slot) before the identical po2 read, pair gate, and patch-row
    matmuls (shared ``_conv_stdp_body`` → bit-identical by construction).

    Args:
      pre_patches: (M, K) im2col spike patches, M = batch x output positions.
      post_spikes: (M, C) current-step output spikes.
      pre_words:   (M, K) uint8 packed history words in the same im2col
                   patch layout as ``pre_patches``.
      post_words:  (M, C) uint8 packed output-history words.
      po2_ltp:     (depth,) LTP read vector (A+ amplitude folded in).
      po2_ltd:     (depth,) LTD read vector (A- amplitude folded in).
      depth:       logical register depth (≤ 8).
      nearest:     nearest-neighbour (True) or all-to-all (False) pairing.
      tile_m:      patch rows per grid step; must divide M.
      interpret:   run through the Pallas interpreter (CPU validation);
                   the default False targets real accelerator hardware.

    Returns the (K, C) float32 delta accumulated over all M patch rows.
    """
    if depth > 8:
        raise ValueError("packed history words support depth <= 8")
    m, kk = pre_patches.shape
    cc = post_spikes.shape[1]
    tm = min(tile_m, m)
    if m % tm:
        raise ValueError(f"tile_m={tm} must divide M={m}")

    kern = functools.partial(_conv_stdp_packed_kernel, depth=depth, nearest=nearest)
    return pl.pallas_call(
        kern,
        grid=(m // tm,),
        in_specs=[
            pl.BlockSpec((tm, kk), lambda i: (i, 0)),  # pre patches
            pl.BlockSpec((tm, cc), lambda i: (i, 0)),  # post spikes
            pl.BlockSpec((tm, kk), lambda i: (i, 0)),  # pre packed words
            pl.BlockSpec((tm, cc), lambda i: (i, 0)),  # post packed words
            pl.BlockSpec((1, depth), lambda i: (0, 0)),  # po2 LTP read vector
            pl.BlockSpec((1, depth), lambda i: (0, 0)),  # po2 LTD read vector
        ],
        out_specs=pl.BlockSpec((kk, cc), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((kk, cc), jnp.float32),
        interpret=interpret,
    )(
        pre_patches.astype(jnp.float32),
        post_spikes.astype(jnp.float32),
        pre_words.astype(jnp.uint8),
        post_words.astype(jnp.uint8),
        po2_ltp.reshape(1, depth).astype(jnp.float32),
        po2_ltd.reshape(1, depth).astype(jnp.float32),
    )
