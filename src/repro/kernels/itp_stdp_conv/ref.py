"""Pure-jnp oracle for the patch-level (im2col) ITP-STDP conv kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def itp_stdp_conv_delta_ref(
    pre_patches: jax.Array,
    post_spikes: jax.Array,
    pre_bits: jax.Array,
    post_bits: jax.Array,
    po2_ltp: jax.Array,
    po2_ltd: jax.Array,
    *,
    nearest: bool = True,
) -> jax.Array:
    """Reference semantics of the fused conv kernel, shapes as in kernel.py.

    Args:
      pre_patches: (M, K) im2col spike patches, M = batch x output positions.
      post_spikes: (M, C) current-step output spikes.
      pre_bits:    (depth, M, K) bitplane patches, k=0 row most recent.
      post_bits:   (depth, M, C) output bitplanes.
      po2_ltp:     (depth,) LTP read vector A+ * 2^(-k/tau').
      po2_ltd:     (depth,) LTD read vector A- * 2^(-k/tau').
      nearest:     nearest-neighbour (True) or all-to-all (False) pairing.

    Returns the (K, C) weight delta summed over all M patch rows.  No
    normalisation, clip, or quantisation — the caller owns those.
    """
    pre = pre_patches.astype(jnp.float32)
    post = post_spikes.astype(jnp.float32)
    pre_b = pre_bits.astype(jnp.float32)
    post_b = post_bits.astype(jnp.float32)
    if nearest:
        # MSB mask (paper Fig. 11): keep only the most recent spike bit
        pre_b = pre_b * (jnp.cumsum(pre_b, axis=0) == 1.0)
        post_b = post_b * (jnp.cumsum(post_b, axis=0) == 1.0)

    ltp_mag = jnp.einsum("d,dmk->mk", po2_ltp.astype(jnp.float32), pre_b)
    ltd_mag = jnp.einsum("d,dmc->mc", po2_ltd.astype(jnp.float32), post_b)

    # pair gate: potentiate where post fired alone, depress where pre fired
    # alone — per (patch element, output channel) synapse, summed over rows
    dw_ltp = jnp.einsum("mk,mc->kc", (1.0 - pre) * ltp_mag, post)
    dw_ltd = jnp.einsum("mk,mc->kc", pre, (1.0 - post) * ltd_mag)
    return dw_ltp - dw_ltd
