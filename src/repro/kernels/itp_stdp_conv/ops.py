"""Public wrappers for the fused im2col ITP-STDP conv kernel.

Bridges model-level state (im2col spike patches + depth-major bitplane
registers, STDPParams) to the raw Pallas kernel, padding the small patch
and channel axes to lane multiples and the patch-row axis to a tile
multiple.  Zero padding is exact here: padded rows and columns carry no
spikes and no history bits, so every gated term they contribute is zero.

:func:`conv_synapse_delta` mirrors ``repro.kernels.itp_stdp.ops.
synapse_delta`` — it returns the raw (K, C) delta so callers own the
batch normalisation, clip, and quantisation.  :func:`im2col_2d` /
:func:`im2col_1d` are the shared patch extractors the SNN conv layers use
for both the spike and the bitplane inputs.
"""

from __future__ import annotations

import jax

from repro.core.stdp import STDPParams, po2_weights
from repro.kernels.dispatch import LANE, SUBLANE
from repro.kernels.dispatch import pad_axis as _pad_axis
from repro.kernels.dispatch import round_up as _round_up
from repro.kernels.itp_stdp_conv.kernel import itp_stdp_conv_delta
from repro.kernels.itp_stdp_conv.ref import itp_stdp_conv_delta_ref


def im2col_2d(x: jax.Array, k: int, stride: int) -> jax.Array:
    """(B, H, W, C) -> (B, Ho, Wo, k*k*C) im2col patches."""
    B, H, W, C = x.shape
    p = jax.lax.conv_general_dilated_patches(
        x.transpose(0, 3, 1, 2), (k, k), (stride, stride), "VALID"
    )
    # p: (B, C*k*k, Ho, Wo) with feature order (C, kh, kw)
    Ho, Wo = p.shape[2], p.shape[3]
    p = p.reshape(B, C, k * k, Ho, Wo).transpose(0, 3, 4, 2, 1)
    return p.reshape(B, Ho, Wo, k * k * C)


def im2col_1d(x: jax.Array, k: int, stride: int) -> jax.Array:
    """(B, L, C) -> (B, Lo, k*C) im2col patches."""
    B, L, C = x.shape
    p = jax.lax.conv_general_dilated_patches(
        x.transpose(0, 2, 1)[..., None], (k, 1), (stride, 1), "VALID"
    )
    Lo = p.shape[2]
    p = p.reshape(B, C, k, Lo).transpose(0, 3, 2, 1)
    return p.reshape(B, Lo, k * C)


def conv_synapse_delta(
    pre_patches: jax.Array,
    post_spikes: jax.Array,
    pre_bits: jax.Array,
    post_bits: jax.Array,
    params: STDPParams,
    *,
    pairing: str = "nearest",
    compensate: bool = True,
    use_kernel: bool = True,
    interpret: bool = True,
    tile_m: int = 128,
) -> jax.Array:
    """Raw (K, C) conv-layer delta from im2col patches + bitplane registers.

    ``pre_patches`` (M, K) / ``post_spikes`` (M, C) are the current-step
    spikes and ``pre_bits`` (depth, M, K) / ``post_bits`` (depth, M, C)
    the depth-major history registers gathered into the same patch layout
    (k=0 row most recent); M flattens batch x output positions.  Callers
    apply the eta / (B * P) normalisation, clip, and quantisation — the
    delta is linear in its gate terms, so accumulation over rows commutes
    with the kernel (the same contract as the dense ``synapse_delta``).
    """
    m, kk = pre_patches.shape
    cc = post_spikes.shape[1]
    depth = pre_bits.shape[0]
    po2_ltp = params.a_plus * po2_weights(depth, params.tau_plus, compensate=compensate)
    po2_ltd = params.a_minus * po2_weights(depth, params.tau_minus, compensate=compensate)
    nearest = pairing == "nearest"
    if not use_kernel:
        return itp_stdp_conv_delta_ref(
            pre_patches,
            post_spikes,
            pre_bits,
            post_bits,
            po2_ltp,
            po2_ltd,
            nearest=nearest,
        )

    tm = min(tile_m, _round_up(m, SUBLANE))
    pm = _round_up(m, tm)
    pk = _round_up(kk, LANE)
    pc = _round_up(cc, LANE)
    out = itp_stdp_conv_delta(
        _pad_axis(_pad_axis(pre_patches, pm, 0), pk, 1),
        _pad_axis(_pad_axis(post_spikes, pm, 0), pc, 1),
        _pad_axis(_pad_axis(pre_bits, pm, 1), pk, 2),
        _pad_axis(_pad_axis(post_bits, pm, 1), pc, 2),
        po2_ltp,
        po2_ltd,
        nearest=nearest,
        tile_m=tm,
        interpret=interpret,
    )
    return out[:kk, :cc]
