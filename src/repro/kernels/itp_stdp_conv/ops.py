"""Public wrappers for the fused im2col ITP-STDP conv kernel.

Bridges model-level state (im2col spike patches + history registers,
STDPParams) to the raw Pallas kernels, padding the small patch and channel
axes to lane multiples and the patch-row axis to a tile multiple.  Zero
padding is exact here: padded rows and columns carry no spikes and no
history bits, so every gated term they contribute is zero.

Two history datapaths share the entry-point shape:

  * :func:`conv_synapse_delta_packed` — **packed** uint8 register words,
    one byte per patch element, im2col'd **once** via the dtype-preserving
    :func:`im2col_words_2d` / :func:`im2col_words_1d` gather instead of
    materialising ``(depth, M, K)`` float32 bitplane patches in HBM;
  * :func:`conv_synapse_delta` — unpacked depth-major bitplane patches
    (the oracle the packed path is pinned against).

Both mirror ``repro.kernels.itp_stdp.ops.synapse_delta`` — they return the
raw (K, C) delta so callers own the batch normalisation, clip, and
quantisation.  :func:`im2col_2d` / :func:`im2col_1d` are the shared float
patch extractors the SNN conv layers use for the spike inputs.
``interpret=None`` derives the interpreter flag from the host
(``repro.kernels.dispatch.default_interpret``) so the fused path is never
silently interpreted on real accelerators.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.history import unpack_words
from repro.core.stdp import STDPParams, po2_weights
from repro.kernels.dispatch import LANE, SUBLANE, default_interpret
from repro.kernels.dispatch import pad_axis as _pad_axis
from repro.kernels.dispatch import round_up as _round_up
from repro.kernels.itp_stdp_conv.kernel import itp_stdp_conv_delta, itp_stdp_conv_delta_packed
from repro.kernels.itp_stdp_conv.ref import itp_stdp_conv_delta_ref


def im2col_2d(x: jax.Array, k: int, stride: int) -> jax.Array:
    """(B, H, W, C) -> (B, Ho, Wo, k*k*C) im2col patches."""
    B, H, W, C = x.shape
    p = jax.lax.conv_general_dilated_patches(
        x.transpose(0, 3, 1, 2), (k, k), (stride, stride), "VALID"
    )
    # p: (B, C*k*k, Ho, Wo) with feature order (C, kh, kw)
    Ho, Wo = p.shape[2], p.shape[3]
    p = p.reshape(B, C, k * k, Ho, Wo).transpose(0, 3, 4, 2, 1)
    return p.reshape(B, Ho, Wo, k * k * C)


def im2col_1d(x: jax.Array, k: int, stride: int) -> jax.Array:
    """(B, L, C) -> (B, Lo, k*C) im2col patches."""
    B, L, C = x.shape
    p = jax.lax.conv_general_dilated_patches(
        x.transpose(0, 2, 1)[..., None], (k, 1), (stride, 1), "VALID"
    )
    Lo = p.shape[2]
    p = p.reshape(B, C, k, Lo).transpose(0, 3, 2, 1)
    return p.reshape(B, Lo, k * C)


def im2col_words_2d(x: jax.Array, k: int, stride: int) -> jax.Array:
    """(B, H, W, C) -> (B, Ho, Wo, k*k*C) dtype-preserving im2col gather.

    Patch extraction for the packed uint8 history words: a pure gather
    (im2col is a copy), so the words cross memory once at one byte per
    patch element — no float cast, no per-depth replication.  Feature
    ordering matches :func:`im2col_2d` exactly ((kh, kw, c) row-major).
    """
    B, H, W, C = x.shape
    ho = (H - k) // stride + 1
    wo = (W - k) // stride + 1
    oh = (jnp.arange(ho) * stride)[:, None, None, None, None]
    ow = (jnp.arange(wo) * stride)[None, :, None, None, None]
    kh = jnp.arange(k)[None, None, :, None, None]
    kw = jnp.arange(k)[None, None, None, :, None]
    idx = ((oh + kh) * W + (ow + kw)) * C + jnp.arange(C)[None, None, None, None, :]
    out = x.reshape(B, H * W * C)[:, idx.reshape(-1)]
    return out.reshape(B, ho, wo, k * k * C)


def im2col_words_1d(x: jax.Array, k: int, stride: int) -> jax.Array:
    """(B, L, C) -> (B, Lo, k*C) dtype-preserving im2col gather.

    1-D twin of :func:`im2col_words_2d`; feature ordering matches
    :func:`im2col_1d` exactly ((kk, c) row-major).
    """
    B, L, C = x.shape
    lo = (L - k) // stride + 1
    pos = (jnp.arange(lo) * stride)[:, None, None] + jnp.arange(k)[None, :, None]
    idx = pos * C + jnp.arange(C)[None, None, :]
    out = x.reshape(B, L * C)[:, idx.reshape(-1)]
    return out.reshape(B, lo, k * C)


def conv_synapse_delta(
    pre_patches: jax.Array,
    post_spikes: jax.Array,
    pre_bits: jax.Array,
    post_bits: jax.Array,
    params: STDPParams,
    *,
    pairing: str = "nearest",
    compensate: bool = True,
    use_kernel: bool = True,
    interpret: bool | None = None,
    tile_m: int = 128,
) -> jax.Array:
    """Raw (K, C) conv-layer delta from im2col patches + bitplane registers.

    ``pre_patches`` (M, K) / ``post_spikes`` (M, C) are the current-step
    spikes and ``pre_bits`` (depth, M, K) / ``post_bits`` (depth, M, C)
    the depth-major history registers gathered into the same patch layout
    (k=0 row most recent); M flattens batch x output positions.  Callers
    apply the eta / (B * P) normalisation, clip, and quantisation — the
    delta is linear in its gate terms, so accumulation over rows commutes
    with the kernel (the same contract as the dense ``synapse_delta``).
    """
    m, kk = pre_patches.shape
    cc = post_spikes.shape[1]
    depth = pre_bits.shape[0]
    po2_ltp = params.a_plus * po2_weights(depth, params.tau_plus, compensate=compensate)
    po2_ltd = params.a_minus * po2_weights(depth, params.tau_minus, compensate=compensate)
    nearest = pairing == "nearest"
    if not use_kernel:
        return itp_stdp_conv_delta_ref(
            pre_patches,
            post_spikes,
            pre_bits,
            post_bits,
            po2_ltp,
            po2_ltd,
            nearest=nearest,
        )

    tm = min(tile_m, _round_up(m, SUBLANE))
    pm = _round_up(m, tm)
    pk = _round_up(kk, LANE)
    pc = _round_up(cc, LANE)
    out = itp_stdp_conv_delta(
        _pad_axis(_pad_axis(pre_patches, pm, 0), pk, 1),
        _pad_axis(_pad_axis(post_spikes, pm, 0), pc, 1),
        _pad_axis(_pad_axis(pre_bits, pm, 1), pk, 2),
        _pad_axis(_pad_axis(post_bits, pm, 1), pc, 2),
        po2_ltp,
        po2_ltd,
        nearest=nearest,
        tile_m=tm,
        interpret=default_interpret() if interpret is None else interpret,
    )
    return out[:kk, :cc]


def conv_synapse_delta_packed(
    pre_patches: jax.Array,
    post_spikes: jax.Array,
    pre_words: jax.Array,
    post_words: jax.Array,
    params: STDPParams,
    *,
    depth: int,
    pairing: str = "nearest",
    compensate: bool = True,
    use_kernel: bool = True,
    interpret: bool | None = None,
    tile_m: int = 128,
) -> jax.Array:
    """Raw (K, C) conv-layer delta from packed uint8 history words.

    The packed twin of :func:`conv_synapse_delta`: ``pre_words`` (M, K) /
    ``post_words`` (M, C) carry one uint8 register word per patch element
    (``repro.core.history.pack_words``, MSB = most recent) gathered into
    the im2col layout by :func:`im2col_words_2d` / :func:`im2col_words_1d`
    — ``4·depth``× less history traffic than the ``(depth, M, ·)`` float32
    bitplane patches.  Zero padding is exact (a zero word carries no
    history bits).  Bit-identical to the unpacked kernel path (shared
    fused body) and pinned against it by tests/test_conv_backend.py.
    """
    m, kk = pre_patches.shape
    cc = post_spikes.shape[1]
    po2_ltp = params.a_plus * po2_weights(depth, params.tau_plus, compensate=compensate)
    po2_ltd = params.a_minus * po2_weights(depth, params.tau_minus, compensate=compensate)
    nearest = pairing == "nearest"
    if not use_kernel:
        return itp_stdp_conv_delta_ref(
            pre_patches,
            post_spikes,
            jnp.transpose(unpack_words(pre_words, depth), (2, 0, 1)),
            jnp.transpose(unpack_words(post_words, depth), (2, 0, 1)),
            po2_ltp,
            po2_ltd,
            nearest=nearest,
        )

    tm = min(tile_m, _round_up(m, SUBLANE))
    pm = _round_up(m, tm)
    pk = _round_up(kk, LANE)
    pc = _round_up(cc, LANE)
    out = itp_stdp_conv_delta_packed(
        _pad_axis(_pad_axis(pre_patches, pm, 0), pk, 1),
        _pad_axis(_pad_axis(post_spikes, pm, 0), pc, 1),
        _pad_axis(_pad_axis(pre_words.astype(jnp.uint8), pm, 0), pk, 1),
        _pad_axis(_pad_axis(post_words.astype(jnp.uint8), pm, 0), pc, 1),
        po2_ltp,
        po2_ltd,
        depth=depth,
        nearest=nearest,
        tile_m=tm,
        interpret=default_interpret() if interpret is None else interpret,
    )
    return out[:kk, :cc]
