"""One dispatch layer for every weight-update datapath.

Before this module existed, every consumer of a :class:`LearningRule`
re-implemented the same three-way branch: resolve the backend
(``reference | fused | fused_interpret | sparse``), pick the packed or
unpacked readout layout, and call the matching rule hook with the right
shape plumbing — once in the engine, once per shard_map tile in the
sharded engine, and three more times in the SNN layers (fc fused, fc
sparse, conv).  An :class:`UpdatePlan` owns that cross-product exactly
once:

  * :func:`make_plan` resolves a config (``EngineConfig`` /
    ``SNNConfig`` duck-type) into a static plan — rule object, backend
    flags, packed-readout selection, effective compensation — at trace
    time;
  * :meth:`UpdatePlan.update` is the dense engine update (fused kernel /
    event-driven with silent-step skip / reference rank-1 path, plus
    clip);
  * :meth:`UpdatePlan.tile_update` is the shard_map tile body (same
    three-way dispatch on tile-local operands, including the global→tile
    event-index translation);
  * :meth:`UpdatePlan.state_readout` / :meth:`UpdatePlan.readout_ndim` /
    :meth:`UpdatePlan.pre_events_crossing` produce the replicated views
    that cross shard_map and the partition-spec shape to ship them with;
  * :meth:`UpdatePlan.fc_delta` / :meth:`UpdatePlan.conv_delta` are the
    batched SNN layer deltas (raw Δw — the layer owns eta / batch
    normalisation / clip / quantise).

Consumers (``repro.core.engine``, ``repro.core.engine_sharded``,
``repro.models.snn``, and everything above them) call only this module;
the rule hooks themselves (``kernel_readout`` / ``*_from_readout``) are
an implementation seam between the plan and the kernel packages, called
nowhere else (lint rule R8 in ``repro.analysis.astlint``).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.stdp import STDPParams, pair_gate
from repro.kernels.dispatch import (im2col_1d, im2col_2d, im2col_words_1d,
                                    im2col_words_2d, spike_events)
from repro.plasticity.base import LearningRule, resolve_rule_backend


@dataclasses.dataclass(frozen=True)
class UpdatePlan:
    """Static dispatch decisions for one (rule, backend, config) cell.

    Built once per trace by :func:`make_plan`; every method is pure and
    jit/vmap/shard_map friendly (all fields are Python statics except
    ``stdp``, whose leaves are floats baked into the trace).
    """

    rule: LearningRule
    backend: str
    use_kernel: bool       # fused / fused_interpret
    interpret: bool
    sparse: bool           # event-driven datapath
    packed: bool           # resolved packed-word selection (depth <= 8)
    depth: int
    pairing: str
    compensate: bool       # effective (rule-override-resolved) flag
    stdp: STDPParams
    eta: float
    w_min: float
    w_max: float
    max_events: int | None

    # -- readout views (shard_map crossing) -----------------------------

    def state_readout(self, state: Any) -> jax.Array:
        """The per-neuron view of the timing state that crosses shard_map.

        Kernel and sparse backends ship the rule's kernel layout (packed
        ``(n,)`` uint8 words by default — the paper's register file);
        the reference backend ships the dense float rows its magnitude
        read is defined on.
        """
        if self.use_kernel or self.sparse:
            return self.rule.kernel_readout(state, packed=self.packed)
        return self.rule.readout(state).astype(jnp.float32)

    def readout_ndim(self) -> int:
        """ndim of :meth:`state_readout` (1 = words → shard axis 0,
        2 = rows → shard axis 1), known before any state exists."""
        if self.use_kernel or self.sparse:
            return self.rule.kernel_readout_axes(packed=self.packed)
        return 2

    # -- session serialization (the serving "plasticity cache") ---------
    # The serving layer (repro.serve) keeps each user's timing state as
    # the rule's packed uint8 word planes and rehydrates them around
    # every batched step; like the kernel hooks, the rule methods behind
    # these (``serve_words`` / ``state_from_words``) are called only
    # here (lint rule R8) so serving code never touches a rule layout.

    def words_per_neuron(self) -> int:
        """Resident uint8 words per neuron of the serialized timing state
        (1 for the history/counter words, 2 for mstdp's history +
        eligibility pair) — the bytes-per-neuron the serving store and
        ``benchmarks/serve_cost.py`` account."""
        return self.rule.words_per_neuron()

    def init_words(self, n: int) -> tuple[jax.Array, ...]:
        """Serialized fresh timing state for a population of ``n``."""
        return self.session_words(self.rule.init_state(n, self.depth))

    def session_words(self, state: Any) -> tuple[jax.Array, ...]:
        """Canonical ``(n,)`` uint8 word planes of a timing state."""
        return self.rule.serve_words(state)

    def session_state(self, words: tuple[jax.Array, ...]) -> Any:
        """Rebuild a timing state whose continued trajectory bit-matches
        the state :meth:`session_words` serialized."""
        return self.rule.state_from_words(words, depth=self.depth)

    def pre_events_crossing(self, pre_spikes: jax.Array) -> jax.Array:
        """Replicated global pre-event index vector for shard_map.

        Sparse backend: the static-shape event list extracted once from
        the replicated pre spikes (each tile translates it locally, see
        :meth:`tile_update`).  Dense backends cross a zero-length vector.
        """
        if not self.sparse:
            return jnp.zeros((0,), jnp.int32)
        events, _ = spike_events(pre_spikes, self.max_events)
        return events

    # -- dense engine update --------------------------------------------

    def update(self, w: jax.Array, pre_spikes: jax.Array,
               post_spikes: jax.Array, pre_state: Any,
               post_state: Any) -> jax.Array:
        """Full clipped update of the dense ``(n_pre, n_post)`` matrix.

        The engine's step-3 datapath: fused Pallas RMW, event-driven
        gather/scatter with the silent-step skip (a step with no event on
        either side is identically zero through the XOR pair gate, so
        ``lax.cond`` skips it outright), or the reference rank-1 gated
        outer product + clip.
        """
        rule = self.rule
        if self.use_kernel:
            return rule.fused_update_from_readout(
                w, pre_spikes, post_spikes,
                rule.kernel_readout(pre_state, packed=self.packed),
                rule.kernel_readout(post_state, packed=self.packed),
                self.stdp, depth=self.depth, pairing=self.pairing,
                compensate=self.compensate, eta=self.eta, w_min=self.w_min,
                w_max=self.w_max, interpret=self.interpret)
        if self.sparse:
            pre_read = rule.kernel_readout(pre_state, packed=self.packed)
            post_read = rule.kernel_readout(post_state, packed=self.packed)

            def _sparse_update(w):
                return rule.sparse_update_from_readout(
                    w, pre_spikes, post_spikes, pre_read, post_read,
                    self.stdp, depth=self.depth, pairing=self.pairing,
                    compensate=self.compensate, eta=self.eta,
                    w_min=self.w_min, w_max=self.w_max,
                    max_events=self.max_events)

            any_event = jnp.any(pre_spikes != 0) | jnp.any(post_spikes)
            return jax.lax.cond(any_event, _sparse_update, lambda w: w, w)
        dw = rule.delta(pre_state, post_state, pre_spikes, post_spikes,
                        self.stdp, depth=self.depth, pairing=self.pairing,
                        compensate=self.compensate)
        return jnp.clip(w + self.eta * dw, self.w_min, self.w_max)

    # -- shard_map tile update ------------------------------------------

    def tile_update(self, w: jax.Array, pre_spikes: jax.Array,
                    post_spikes: jax.Array, pre_read: jax.Array,
                    post_read: jax.Array, *,
                    pre_events: jax.Array | None = None,
                    pre_axis: str | None = None) -> jax.Array:
        """Clipped update of one local ``(pre_tile, post_tile)`` tile.

        Same three-way dispatch as :meth:`update`, but on tile-local
        operands: the readout views arrive pre-sliced by shard_map, and
        for the sparse backend the replicated *global* event indices in
        ``pre_events`` are translated into this tile's row range
        (out-of-tile events map to the out-of-range sentinel ``tile`` so
        the ``mode="drop"`` scatters ignore them — negative indices would
        wrap, hence the explicit remap).
        """
        rule = self.rule
        if self.use_kernel:
            return rule.fused_update_from_readout(
                w, pre_spikes, post_spikes, pre_read, post_read, self.stdp,
                depth=self.depth, pairing=self.pairing,
                compensate=self.compensate, eta=self.eta, w_min=self.w_min,
                w_max=self.w_max, interpret=self.interpret)
        if self.sparse:
            tile = w.shape[0]
            local = pre_events
            if pre_axis is not None:
                start = jax.lax.axis_index(pre_axis) * tile
                local = pre_events - start
                local = jnp.where((local >= 0) & (local < tile), local, tile)
            return rule.sparse_update_from_readout(
                w, pre_spikes, post_spikes, pre_read, post_read, self.stdp,
                depth=self.depth, pairing=self.pairing,
                compensate=self.compensate, eta=self.eta, w_min=self.w_min,
                w_max=self.w_max, max_events=self.max_events,
                pre_events=local)
        ltp = rule.magnitudes_from_readout(
            pre_read, self.stdp.a_plus, self.stdp.tau_plus,
            depth=self.depth, pairing=self.pairing,
            compensate=self.compensate)
        ltd = rule.magnitudes_from_readout(
            post_read, self.stdp.a_minus, self.stdp.tau_minus,
            depth=self.depth, pairing=self.pairing,
            compensate=self.compensate)
        ltp_en, ltd_en = pair_gate(pre_spikes[:, None], post_spikes[None, :])
        dw = ltp_en * ltp[:, None] - ltd_en * ltd[None, :]
        return jnp.clip(w + self.eta * dw, self.w_min, self.w_max)

    # -- batched SNN layer deltas ---------------------------------------

    def _batched_readouts(self, pre_state: Any, post_state: Any,
                          batch: int) -> tuple[jax.Array, jax.Array]:
        """Per-sample kernel readout views for the fc paths.

        Word readouts ((B·n,) uint8 — packed register / counter words)
        reshape to ``(B, n)``; row readouts ((rows, B·n)) to per-sample
        ``(B, rows, n)`` views (row count is rule-specific — ``depth``
        bitplanes for the history rules, one counter row, history+trace
        rows for composite-state rules).
        """
        pre_read = self.rule.kernel_readout(pre_state, packed=self.packed)
        post_read = self.rule.kernel_readout(post_state, packed=self.packed)
        if pre_read.ndim == 1:
            pre_read = pre_read.reshape(batch, -1)
            post_read = post_read.reshape(batch, -1)
        else:
            pre_read = pre_read.reshape(
                pre_read.shape[0], batch, -1).transpose(1, 0, 2)
            post_read = post_read.reshape(
                post_read.shape[0], batch, -1).transpose(1, 0, 2)
        return pre_read, post_read

    def fc_delta(self, pre_state: Any, post_state: Any, s_in: jax.Array,
                 s_out: jax.Array) -> jax.Array:
        """Batch-summed raw ``(fan_in, n_out)`` Δw for an fc layer.

        The fc layer is the engine's dense synapse matrix replicated over
        the batch: the fused and sparse backends vmap the rule's
        per-sample delta hook and accumulate; the reference backend is
        the einsum form of the same pair-gated rank-1 update (P = 1
        special case of the conv patch formula).  Raw delta — the layer
        owns eta / B normalisation / clip / quantise.
        """
        B = s_in.shape[0]
        pre = s_in.reshape(B, -1)                       # (B, fan_in)
        post = s_out.reshape(B, -1)                     # (B, n_out)
        if not (self.use_kernel or self.sparse):
            ltp = self.rule.magnitudes(
                pre_state, self.stdp.a_plus, self.stdp.tau_plus,
                depth=self.depth, pairing=self.pairing,
                compensate=self.compensate)
            ltd = self.rule.magnitudes(
                post_state, self.stdp.a_minus, self.stdp.tau_minus,
                depth=self.depth, pairing=self.pairing,
                compensate=self.compensate)
            ltp_p = ltp.reshape(B, 1, -1)               # (B, P=1, fan_in)
            pre_p = pre.reshape(B, 1, -1)
            post_s = post.reshape(B, 1, -1)
            ltd_m = ltd.reshape(B, 1, -1)
            # pair gate (§V-A): potentiate where post fired alone,
            # depress where pre fired alone
            dw_ltp = jnp.einsum("bpk,bpc->kc", (1.0 - pre_p) * ltp_p, post_s)
            dw_ltd = jnp.einsum("bpk,bpc->kc", pre_p, (1.0 - post_s) * ltd_m)
            return dw_ltp - dw_ltd
        pre_read, post_read = self._batched_readouts(pre_state, post_state, B)
        if self.sparse:
            def one(p, q, pr, qr):
                return self.rule.sparse_delta_from_readout(
                    p, q, pr, qr, self.stdp, depth=self.depth,
                    pairing=self.pairing, compensate=self.compensate,
                    max_events=self.max_events)
        else:
            def one(p, q, pr, qr):
                return self.rule.fused_delta_from_readout(
                    p, q, pr, qr, self.stdp, depth=self.depth,
                    pairing=self.pairing, compensate=self.compensate,
                    interpret=self.interpret)
        return jax.vmap(one)(pre, post, pre_read, post_read).sum(axis=0)

    def conv_delta(self, pre_state: Any, post_state: Any,
                   patches: jax.Array, s_out: jax.Array, *,
                   in_shape: tuple, kind: str, kernel: int,
                   stride: int) -> jax.Array:
        """Batch+position-summed raw ``(K, C)`` Δw for a conv layer.

        The conv STDP update is the dense pair rule per (patch element →
        output channel) synapse accumulated over batch and spatial
        positions; the timing readout is gathered into the same im2col
        layout as the spikes (readout commutes with the gather — each
        patch element carries its source pixel's timing state).  Packed
        word readouts gather once as ``(M, K)`` uint8; row readouts
        materialise ``(rows, M, ·)`` float patches (the oracle layout).
        Dispatches to the rule's sparse conv hook (``backend="sparse"``)
        or its conv kernel/oracle hook otherwise.
        """
        rule = self.rule
        B = s_out.shape[0]
        packed = self.use_kernel and self.packed
        pre_read = rule.kernel_readout(pre_state, packed=packed)
        post_read = rule.kernel_readout(post_state, packed=packed)
        if pre_read.ndim == 1:
            # per-neuron word readout: im2col the (M, K) uint8 words once
            im2col_w = im2col_words_2d if kind == "conv2d" else im2col_words_1d
            pre_read = im2col_w(pre_read.reshape((B,) + tuple(in_shape)),
                                kernel, stride)
            pre_read = pre_read.reshape(-1, pre_read.shape[-1])      # (M, K)
            post_read = post_read.reshape(-1, s_out.shape[-1])       # (M, C)
        else:
            # dense row layout: (rows, M, ·) float32 patches
            im2col = im2col_2d if kind == "conv2d" else im2col_1d
            rows = pre_read.shape[0]
            pre_read = pre_read.astype(jnp.float32)
            pre_read = pre_read.reshape((rows, B) + tuple(in_shape))
            pre_read = jax.vmap(
                lambda p: im2col(p, kernel, stride))(pre_read)
            pre_read = pre_read.reshape(rows, -1, pre_read.shape[-1])
            post_read = post_read.astype(jnp.float32).reshape(
                rows, -1, s_out.shape[-1])
        pre_patches = patches.reshape(-1, patches.shape[-1])         # (M, K)
        post_spikes = s_out.reshape(-1, s_out.shape[-1])             # (M, C)
        if self.sparse:
            return rule.sparse_conv_delta_from_readout(
                pre_patches, post_spikes, pre_read, post_read, self.stdp,
                depth=self.depth, pairing=self.pairing,
                compensate=self.compensate, max_events=self.max_events)
        return rule.conv_delta_from_readout(
            pre_patches, post_spikes, pre_read, post_read, self.stdp,
            depth=self.depth, pairing=self.pairing,
            compensate=self.compensate, use_kernel=self.use_kernel,
            interpret=self.interpret)


def make_plan(cfg: Any) -> UpdatePlan:
    """Resolve a config into an :class:`UpdatePlan`.

    Duck-typed over ``EngineConfig`` and ``SNNConfig``: both carry
    ``rule`` / ``backend`` / ``depth`` / ``pairing`` / ``stdp`` /
    ``eta`` / ``max_events`` plus ``learning_rule()`` and
    ``use_packed_history()``; the engine's clip window
    (``w_min``/``w_max``) defaults to the SNN's fixed [0, 1] when the
    config has none, and compensation resolves through
    ``effective_compensate()`` where available (EngineConfig) or the
    ``compensate`` property (SNNConfig).
    """
    rule = cfg.learning_rule()
    use_kernel, interpret = resolve_rule_backend(rule, cfg.backend)
    if hasattr(cfg, "effective_compensate"):
        compensate = cfg.effective_compensate()
    else:
        compensate = cfg.compensate
    return UpdatePlan(
        rule=rule,
        backend=cfg.backend,
        use_kernel=use_kernel,
        interpret=interpret,
        sparse=cfg.backend == "sparse",
        packed=cfg.use_packed_history(),
        depth=cfg.depth,
        pairing=cfg.pairing,
        compensate=compensate,
        stdp=cfg.stdp,
        eta=cfg.eta,
        w_min=getattr(cfg, "w_min", 0.0),
        w_max=getattr(cfg, "w_max", 1.0),
        max_events=cfg.max_events,
    )


def apply_update(cfg: Any, w: jax.Array, pre_spikes: jax.Array,
                 post_spikes: jax.Array, pre_state: Any,
                 post_state: Any) -> jax.Array:
    """One-shot convenience: :func:`make_plan` + :meth:`UpdatePlan.update`."""
    return make_plan(cfg).update(w, pre_spikes, post_spikes,
                                 pre_state, post_state)
