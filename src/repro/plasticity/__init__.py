"""Pluggable learning rules: the API seam for the paper's STDP-variant
comparison (rule × backend matrix in ROADMAP.md)."""

from repro.plasticity.base import (
    BACKENDS,
    RULES,
    LearningRule,
    get_rule,
    kernel_rule_names,
    register_rule,
    resolve_rule_backend,
    rule_names,
    sparse_rule_names,
    validate_update_config,
)
from repro.plasticity.rules import (
    EXACT,
    IMSTDP,
    ITP,
    ITP_NOCOMP,
    LINEAR,
    CounterRule,
    HistoryRule,
)
