"""Pluggable learning rules: the API seam for the paper's STDP-variant
comparison (rule × backend matrix in ROADMAP.md).

Consumers dispatch weight updates through :mod:`repro.plasticity.apply`
(``make_plan`` / ``UpdatePlan`` / ``apply_update``) — the single layer
that owns backend resolution, packed-readout selection, and the
dense/conv/sharded shape variants.  New rules subclass
:class:`Rank1Rule` (five slim methods, every backend inherited) or
:class:`LearningRule` (hand-tuned hooks) and register by name — see
docs/adding-a-rule.md for the recipe.

``UpdatePlan`` also owns the session-serialization seam the serving
layer (:mod:`repro.serve`) rides: ``words_per_neuron`` / ``init_words``
/ ``session_words`` / ``session_state`` round-trip a rule's timing
state through packed uint8 words (1–2 bytes/neuron) bit-exactly.  The
underlying rule hooks are lint-guarded (R8) like the backend hooks.
"""

from repro.plasticity.apply import UpdatePlan, apply_update, make_plan
from repro.plasticity.base import (
    BACKENDS,
    RULES,
    LearningRule,
    Rank1Rule,
    get_rule,
    kernel_rule_names,
    register_rule,
    resolve_rule_backend,
    rule_names,
    sparse_rule_names,
    validate_update_config,
)
from repro.plasticity.mstdp import MSTDP, MSTDPRule, MSTDPState
from repro.plasticity.rules import (
    EXACT,
    IMSTDP,
    ITP,
    ITP_NOCOMP,
    LINEAR,
    CounterRule,
    HistoryRule,
)
