"""Reward-modulated ITP-STDP (``rule="mstdp"``): the slim protocol's proof.

R-STDP factorised the intrinsic-timing way: instead of a per-pair
eligibility matrix (the conventional O(N²) formulation), each neuron
carries one extra uint8 *eligibility word* next to its bitplane spike
history — a spike injects a fixed credit, and every step decays it by a
power of two (one right shift, the same shift-only arithmetic discipline
as the po2 magnitudes of §IV).  The modulated magnitude is then

    ``m_mstdp = reward * (elig / 128) * m_itp``

— a per-neuron scale on the standard register-read magnitude, so the
synapse matrix still sees only the pair-gated rank-1 outer product and
the rule rides :class:`repro.plasticity.base.Rank1Rule` onto every
backend (reference, fused kernels, event-driven sparse, the sharded
engine) with **zero new kernel code and zero engine/model edits** — the
whole point of the ISSUE-9 dispatch layer.

``reward`` is a static field of the frozen rule instance: like every
other rule hyperparameter it is baked into the jitted program
(``dataclasses.replace(MSTDP, reward=r)`` + re-registration swaps it
between episodes).  The registered default is ``reward=1.0``, which
leaves mstdp a pure eligibility-gated ITP-STDP.

State per neuron: ``depth`` history bits + 8 eligibility bits — one
extra uint8 word in the same register-file format, exactly the storage
story of the paper's 8-bit discipline.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import history as H
from repro.core.stdp import magnitudes_depth_major
from repro.plasticity.base import Rank1Rule, register_rule

# Eligibility word arithmetic: a spike injects 64 (= 0.5 in the /128
# fixed-point read), each step halves by shift.  Saturating at 127 keeps
# decayed (<= 63) + inject (64) inside the uint8 word — never wraps.
ELIG_INJECT = 64
ELIG_MAX = 127
ELIG_SCALE = 128.0  # fixed-point denominator of the eligibility read


class MSTDPState(NamedTuple):
    """Per-population timing state: bitplane history + eligibility word."""

    hist: H.SpikeHistory  # same packed registers as rule="itp"
    elig: jax.Array  # (n,) uint8 eligibility


@dataclasses.dataclass(frozen=True)
class MSTDPRule(Rank1Rule):
    """Reward-modulated intrinsic-timing rule (slim protocol only)."""

    name: str = "mstdp"
    compensate: bool | None = None  # defer to the config flag, like itp
    reward: float = 1.0

    def init_state(self, n: int, depth: int) -> MSTDPState:
        return MSTDPState(H.init_history(n, depth), jnp.zeros((n,), jnp.uint8))

    def step(self, state: MSTDPState, spikes: jax.Array, *, depth: int) -> MSTDPState:
        del depth  # state carries it
        fired = jnp.asarray(spikes).astype(jnp.uint8)
        decayed = state.elig >> 1  # po2 decay: one shift
        elig = jnp.minimum(
            decayed + fired * jnp.uint8(ELIG_INJECT), jnp.uint8(ELIG_MAX)
        )
        return MSTDPState(H.push(state.hist, spikes), elig)

    def readout(self, state: MSTDPState) -> jax.Array:
        # (depth + 1, n) uint8: history planes (k=0 newest) + eligibility
        regs = H.registers_depth_major(state.hist)
        return jnp.concatenate([regs, state.elig[None, :]], axis=0)

    def magnitudes_from_readout(
        self,
        arr: jax.Array,
        amplitude: float,
        tau: float,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
    ) -> jax.Array:
        del depth  # arr carries it (history rows = arr rows - 1)
        base = magnitudes_depth_major(
            arr[:-1], amplitude, tau, pairing=pairing, compensate=compensate
        )
        elig = arr[-1].astype(jnp.float32) / ELIG_SCALE
        return self.reward * elig * base

    def last_spikes(self, state: MSTDPState) -> jax.Array:
        return H.latest(state.hist).astype(jnp.float32)

    # -- session serialization: history word + eligibility word ---------
    # 2 resident bytes/neuron — the serving layer's bytes-per-session
    # ceiling (CI gates <= 2; see benchmarks/serve_cost.py).

    def words_per_neuron(self) -> int:
        return 2

    def serve_words(self, state: MSTDPState) -> tuple[jax.Array, ...]:
        return (H.pack_words(state.hist), state.elig)

    def state_from_words(self, words: tuple[jax.Array, ...], *, depth: int) -> MSTDPState:
        hist_word, elig = words
        return MSTDPState(H.from_words(hist_word, depth), elig.astype(jnp.uint8))


MSTDP = register_rule(MSTDPRule())
