"""The registered learning rules: the paper's rule hierarchy as state
machines (curve-level forms live in ``repro.core.stdp``).

Two families, matching the paper's §I taxonomy:

  * :class:`HistoryRule` — intrinsic timing (this work).  State is the
    bitplane spike history; the timing difference is never computed: the
    register read *is* the update (eq. 2 / Fig. 3).  ``itp`` (compensated
    by default, eq. 18) and ``itp_nocomp`` (raw po2, §IV-A error bound).
    These are the rules the fused Pallas kernels implement.

  * :class:`CounterRule` — conventional explicit-Δt datapaths.  State is
    a per-neuron last-spike counter (saturating at ``depth``); on an
    update the per-pair timing difference is formed and a window function
    evaluated per synapse — the O(n²) transcendental work Tables III-V
    monetise.  ``exact`` (base-e exponential, [26]/[28]-style — the
    CounterEngine of ``repro.core.baseline`` folded into the rule API),
    ``linear`` (the PWL approximation of [24]) and ``imstdp`` (the
    integer-grid LUT of [23]).  The window semantics live in
    ``repro.kernels.itp_counter.ref`` (shared with the fused Pallas
    counter kernels, so the jnp reference and the kernel oracle cannot
    drift); the fused* backends run the same per-pair datapath on-chip
    style — Δt formed in-register from the counter word, window fused
    with the weight accumulate — which is what makes ``rule_cost`` the
    paper's kernel-vs-kernel speedup comparison.

A counter at value t means the neuron last spiked t steps ago (t=0: the
previous step — spikes are recorded *after* the weight update, exactly
like the history shift-in), so nearest-neighbour magnitudes agree with
the history read on the integer grid: ``exact`` with the same ``depth``
is trajectory-identical to compensated ``itp`` — the paper's equivalence
claim, pinned by tests/test_plasticity.py.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import history as H
from repro.core.stdp import STDPParams, magnitudes_depth_major, pair_gate
from repro.kernels.itp_counter.ref import WINDOWS, counter_magnitudes
from repro.plasticity.base import LearningRule, register_rule


@dataclasses.dataclass(frozen=True)
class HistoryRule(LearningRule):
    """Intrinsic-timing po2 rule: bitplane-history state, register-read Δw."""

    name: str = "itp"
    has_kernel: bool = True
    has_sparse: bool = True
    compensate: bool | None = None  # None: defer to the config flag

    def init_state(self, n: int, depth: int) -> H.SpikeHistory:
        return H.init_history(n, depth)

    def step(self, state: H.SpikeHistory, spikes: jax.Array, *, depth: int) -> H.SpikeHistory:
        del depth  # state carries it
        return H.push(state, spikes)

    def readout(self, state: H.SpikeHistory) -> jax.Array:
        return H.registers_depth_major(state)  # (depth, n), k=0 newest

    def readout_packed(self, state: H.SpikeHistory) -> jax.Array:
        return H.pack_words(state)  # (n,) uint8, MSB = newest

    # -- session serialization: one history word per neuron -------------

    def words_per_neuron(self) -> int:
        return 1

    def serve_words(self, state: H.SpikeHistory) -> tuple[jax.Array, ...]:
        return (H.pack_words(state),)

    def state_from_words(self, words: tuple[jax.Array, ...], *, depth: int) -> H.SpikeHistory:
        (word,) = words
        return H.from_words(word, depth)

    def magnitudes_from_readout(
        self,
        arr: jax.Array,
        amplitude: float,
        tau: float,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
    ) -> jax.Array:
        # the rule's compensate override (itp_nocomp) is resolved once at
        # the config level (EngineConfig.effective_compensate /
        # SNNConfig.compensate) — callers pass the resolved flag
        del depth  # arr carries it
        return magnitudes_depth_major(arr, amplitude, tau, pairing=pairing, compensate=compensate)

    def last_spikes(self, state: H.SpikeHistory) -> jax.Array:
        # the newest spike bit is planes[head] directly — reading it via
        # as_register(state)[:, 0] would materialise the full (N, depth)
        # gather+transpose every step just to drop depth-1 columns
        # (equivalence pinned by tests/test_plasticity.py)
        return H.latest(state).astype(jnp.float32)

    # -- fused (kernel) datapath: the itp_stdp / itp_stdp_conv packages --

    def kernel_readout(self, state: H.SpikeHistory, *, packed: bool) -> jax.Array:
        return self.readout_packed(state) if packed else self.readout(state)

    def kernel_readout_axes(self, *, packed: bool) -> int:
        return 1 if packed else 2

    def fused_update_from_readout(
        self,
        w: jax.Array,
        pre_spike: jax.Array,
        post_spike: jax.Array,
        pre_read: jax.Array,
        post_read: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
        eta: float = 1.0,
        w_min: float = 0.0,
        w_max: float = 1.0,
        interpret: bool = False,
    ) -> jax.Array:
        # deferred import: repro.core must stay importable from the kernel
        # packages' own modules (ops.py imports repro.core.history)
        from repro.kernels.itp_stdp.ops import weight_update_depth_major, weight_update_packed

        kw = dict(
            pairing=pairing,
            compensate=compensate,
            eta=eta,
            w_min=w_min,
            w_max=w_max,
            interpret=interpret,
        )
        if pre_read.ndim == 1:  # packed uint8 register words
            return weight_update_packed(
                w, pre_spike, post_spike, pre_read, post_read, p, depth=depth, **kw
            )
        return weight_update_depth_major(w, pre_spike, post_spike, pre_read, post_read, p, **kw)

    def fused_delta_from_readout(
        self,
        pre_spike: jax.Array,
        post_spike: jax.Array,
        pre_read: jax.Array,
        post_read: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
        interpret: bool = False,
    ) -> jax.Array:
        from repro.kernels.itp_stdp.ops import synapse_delta, synapse_delta_packed

        kw = dict(pairing=pairing, compensate=compensate, interpret=interpret)
        if pre_read.ndim == 1:  # packed uint8 register words
            return synapse_delta_packed(
                pre_spike, post_spike, pre_read, post_read, p, depth=depth, **kw
            )
        return synapse_delta(pre_spike, post_spike, pre_read, post_read, p, **kw)

    def conv_delta_from_readout(
        self,
        pre_patches: jax.Array,
        post_spikes: jax.Array,
        pre_read: jax.Array,
        post_read: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
        use_kernel: bool = True,
        interpret: bool = False,
    ) -> jax.Array:
        from repro.kernels.itp_stdp_conv.ops import conv_synapse_delta, conv_synapse_delta_packed

        kw = dict(
            pairing=pairing,
            compensate=compensate,
            use_kernel=use_kernel,
            interpret=interpret,
        )
        if pre_read.ndim == 2:  # (M, K) packed words (bitplanes are (depth, M, K))
            return conv_synapse_delta_packed(
                pre_patches, post_spikes, pre_read, post_read, p, depth=depth, **kw
            )
        return conv_synapse_delta(pre_patches, post_spikes, pre_read, post_read, p, **kw)

    # -- event-driven (sparse) datapath: the itp_sparse package ---------

    def _readout_rows(self, arr: jax.Array, depth: int) -> jax.Array:
        """Normalise a readout view to (depth, n) registers, k=0 newest.

        Accepts either the packed uint8 word layout ((n,), the format
        that crosses shard_map) or the dense depth-major rows; unpacking
        is bit-exact, so both produce identical magnitudes.
        """
        if arr.ndim == 1:  # packed uint8 register words
            return H.unpack_words(arr, depth).T
        return arr

    def sparse_update_from_readout(
        self,
        w: jax.Array,
        pre_spike: jax.Array,
        post_spike: jax.Array,
        pre_read: jax.Array,
        post_read: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
        eta: float = 1.0,
        w_min: float = 0.0,
        w_max: float = 1.0,
        max_events: int | None = None,
        pre_events: jax.Array | None = None,
        post_events: jax.Array | None = None,
    ) -> jax.Array:
        from repro.kernels.itp_sparse.ops import sparse_weight_update

        kw = dict(depth=depth, pairing=pairing, compensate=compensate)
        ltp = self.magnitudes_from_readout(
            self._readout_rows(pre_read, depth), p.a_plus, p.tau_plus, **kw
        )
        ltd = self.magnitudes_from_readout(
            self._readout_rows(post_read, depth), p.a_minus, p.tau_minus, **kw
        )
        return sparse_weight_update(
            w,
            pre_spike,
            post_spike,
            ltp,
            ltd,
            eta=eta,
            w_min=w_min,
            w_max=w_max,
            max_events=max_events,
            pre_events=pre_events,
            post_events=post_events,
        )

    def sparse_delta_from_readout(
        self,
        pre_spike: jax.Array,
        post_spike: jax.Array,
        pre_read: jax.Array,
        post_read: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
        max_events: int | None = None,
    ) -> jax.Array:
        from repro.kernels.itp_sparse.ops import sparse_synapse_delta

        kw = dict(depth=depth, pairing=pairing, compensate=compensate)
        ltp = self.magnitudes_from_readout(
            self._readout_rows(pre_read, depth), p.a_plus, p.tau_plus, **kw
        )
        ltd = self.magnitudes_from_readout(
            self._readout_rows(post_read, depth), p.a_minus, p.tau_minus, **kw
        )
        return sparse_synapse_delta(pre_spike, post_spike, ltp, ltd, max_events=max_events)

    def sparse_conv_delta_from_readout(
        self,
        pre_patches: jax.Array,
        post_spikes: jax.Array,
        pre_read: jax.Array,
        post_read: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
        max_events: int | None = None,
    ) -> jax.Array:
        from repro.core.stdp import po2_weights
        from repro.kernels.itp_sparse.ops import sparse_conv_delta

        po2_ltp = p.a_plus * po2_weights(depth, p.tau_plus, compensate=compensate)
        po2_ltd = p.a_minus * po2_weights(depth, p.tau_minus, compensate=compensate)
        return sparse_conv_delta(
            pre_patches,
            post_spikes,
            pre_read,
            post_read,
            po2_ltp,
            po2_ltd,
            nearest=pairing == "nearest",
            max_events=max_events,
        )


@dataclasses.dataclass(frozen=True)
class CounterRule(LearningRule):
    """Conventional Δt-based STDP: last-spike counters + per-pair window.

    Nearest-neighbour only (one counter holds one spike time).  A counter
    saturates at ``depth`` (one past the last valid delay ``depth-1``),
    mirroring the finite history window of the po2 rules.  The fused*
    backends route to ``repro.kernels.itp_counter`` — the same per-pair
    window datapath run on-chip style (Δt broadcast in-register from the
    uint8 counter word, window fused with the weight accumulate), so the
    ``rule_cost`` comparison against the ITP kernels is kernel-vs-kernel.
    """

    name: str = "exact"
    window: str = "exact"
    has_kernel: bool = True
    compensate: bool | None = None

    def _window_fn(self):
        return WINDOWS[self.window]

    def init_state(self, n: int, depth: int) -> jax.Array:
        # start saturated-invalid: no spike within the window yet
        return jnp.full((n,), depth, jnp.int32)

    def step(self, state: jax.Array, spikes: jax.Array, *, depth: int) -> jax.Array:
        fired = jnp.asarray(spikes).astype(bool)
        return jnp.where(fired, 0, jnp.minimum(state + 1, depth)).astype(jnp.int32)

    def readout(self, state: jax.Array) -> jax.Array:
        return state.astype(jnp.float32)[None, :]  # (1, n)

    def readout_packed(self, state: jax.Array) -> jax.Array:
        # the saturating counter IS the word: one uint8 per neuron, the
        # same shape/sharding contract as the packed history words
        # (depth <= 255 so the saturation value always fits)
        return state.astype(jnp.uint8)

    # -- session serialization: the counter word round-trips losslessly -

    def words_per_neuron(self) -> int:
        return 1

    def serve_words(self, state: jax.Array) -> tuple[jax.Array, ...]:
        return (self.readout_packed(state),)

    def state_from_words(self, words: tuple[jax.Array, ...], *, depth: int) -> jax.Array:
        del depth  # counters saturate at depth but the word stores the value
        (word,) = words
        return word.astype(jnp.int32)

    def check_pairing(self, pairing: str) -> None:
        if pairing != "nearest":
            raise ValueError(
                f"rule {self.name!r} is counter-based (one last-spike time "
                f"per neuron) and supports pairing='nearest' only, got "
                f"{pairing!r}"
            )

    def magnitudes_from_readout(
        self,
        arr: jax.Array,
        amplitude: float,
        tau: float,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
    ) -> jax.Array:
        self.check_pairing(pairing)
        return counter_magnitudes(arr[0], amplitude, tau, depth=depth, window=self.window)

    def last_spikes(self, state: jax.Array) -> jax.Array:
        return (state == 0).astype(jnp.float32)

    # -- fused (kernel) datapath: the itp_counter package ---------------

    def kernel_readout(self, state: jax.Array, *, packed: bool) -> jax.Array:
        del packed  # one uint8 counter word per neuron is the only layout
        return self.readout_packed(state)

    def kernel_readout_axes(self, *, packed: bool) -> int:
        del packed
        return 1

    def fused_update_from_readout(
        self,
        w: jax.Array,
        pre_spike: jax.Array,
        post_spike: jax.Array,
        pre_read: jax.Array,
        post_read: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
        eta: float = 1.0,
        w_min: float = 0.0,
        w_max: float = 1.0,
        interpret: bool = False,
    ) -> jax.Array:
        from repro.kernels.itp_counter.ops import counter_weight_update

        self.check_pairing(pairing)
        del compensate  # counter windows read τ directly (no po2 read to fix)
        return counter_weight_update(
            w,
            pre_spike,
            post_spike,
            pre_read,
            post_read,
            p,
            depth=depth,
            window=self.window,
            eta=eta,
            w_min=w_min,
            w_max=w_max,
            interpret=interpret,
        )

    def fused_delta_from_readout(
        self,
        pre_spike: jax.Array,
        post_spike: jax.Array,
        pre_read: jax.Array,
        post_read: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
        interpret: bool = False,
    ) -> jax.Array:
        from repro.kernels.itp_counter.ops import counter_synapse_delta

        self.check_pairing(pairing)
        del compensate
        return counter_synapse_delta(
            pre_spike,
            post_spike,
            pre_read,
            post_read,
            p,
            depth=depth,
            window=self.window,
            interpret=interpret,
        )

    def conv_delta_from_readout(
        self,
        pre_patches: jax.Array,
        post_spikes: jax.Array,
        pre_read: jax.Array,
        post_read: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
        use_kernel: bool = True,
        interpret: bool = False,
    ) -> jax.Array:
        from repro.kernels.itp_counter.ops import conv_counter_synapse_delta

        self.check_pairing(pairing)
        del compensate
        return conv_counter_synapse_delta(
            pre_patches,
            post_spikes,
            pre_read,
            post_read,
            p,
            depth=depth,
            window=self.window,
            use_kernel=use_kernel,
            interpret=interpret,
        )

    def delta(
        self,
        pre_state: jax.Array,
        post_state: jax.Array,
        pre_spikes: jax.Array,
        post_spikes: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
    ) -> jax.Array:
        """Deliberately per-pair: Δt is broadcast to every synapse and the
        window evaluated per pair — the conventional O(n²) datapath the
        intrinsic-timing representation collapses to a register read
        (the measured-cost basis of benchmarks/rule_cost.py)."""
        self.check_pairing(pairing)
        fn = self._window_fn()
        dt_ltp = pre_state[:, None].astype(jnp.float32)  # (n_pre, 1)
        dt_ltd = post_state[None, :].astype(jnp.float32)  # (1, n_post)
        ltp_valid = pre_state[:, None] <= depth - 1
        ltd_valid = post_state[None, :] <= depth - 1
        ltp_mag = fn(dt_ltp, p.a_plus, p.tau_plus, depth) * ltp_valid
        ltd_mag = fn(dt_ltd, p.a_minus, p.tau_minus, depth) * ltd_valid
        ltp_en, ltd_en = pair_gate(pre_spikes[:, None], post_spikes[None, :])
        return ltp_en * ltp_mag - ltd_en * ltd_mag


ITP = register_rule(HistoryRule(name="itp", compensate=None))
ITP_NOCOMP = register_rule(HistoryRule(name="itp_nocomp", compensate=False))
EXACT = register_rule(CounterRule(name="exact", window="exact"))
LINEAR = register_rule(CounterRule(name="linear", window="linear"))
IMSTDP = register_rule(CounterRule(name="imstdp", window="imstdp"))
