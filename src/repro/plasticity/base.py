"""First-class learning-rule abstraction for the update path.

The paper's headline results are *comparative*: ITP-STDP against the
original counter-based exact STDP and simpler approximations on the same
networks — one register-file datapath, a family of rules (Tables III–V).
This module is the platform contract that makes the family real.

The **slim protocol** a rule actually has to write is small — it declares
its timing state, its readout views, and its window/delta semantics:

  * ``init_state``             — the per-population timing state
                                 (bitplane spike histories, last-spike
                                 counters, eligibility traces, …);
  * ``step``                   — recording the current step's spikes
                                 (the hardware 'shift-in' / counter
                                 reset / trace decay);
  * ``readout``                — a dense ``(rows, n)`` view of that
                                 state (the arrays-only form shard_map
                                 and the oracles consume);
  * ``magnitudes_from_readout``— the per-neuron Δw magnitude read from
                                 such a view (the rank-1 window
                                 semantics);
  * ``last_spikes``            — the k=0 spike indicator (lateral
                                 inhibition).

Everything *backend*-shaped on top of that — which kernel runs, packed
vs unpacked operands, dense vs conv vs sharded shape plumbing — lives in
exactly one place, ``repro.plasticity.apply``: consumers build an
``UpdatePlan`` from their config and never branch on backends or call a
hook themselves (machine-checked by lint rule R8).  The plan talks to
rules through the hook seam defined here (``kernel_readout`` /
``*_from_readout``), and :class:`Rank1Rule` implements that entire seam
generically for any rule whose update is a pair-gated rank-1 outer
product of per-neuron magnitudes: the generic adapters feed the
magnitude vectors through the existing ``itp_stdp`` / ``itp_stdp_conv``
/ ``itp_sparse`` datapaths as a single depth-1 plane with unit po2
weights, so a new rule inherits the fused, sparse, conv, and sharded
machinery from its five slim methods with zero kernel code.

The built-in families predate :class:`Rank1Rule` and keep their
hand-tuned hooks: the intrinsic-timing rules route to the ``itp_stdp``
/ ``itp_stdp_conv`` kernels on the packed register words, the
explicit-Δt counter family to the ``itp_counter`` kernels on its uint8
counter word.  ``has_kernel``/``has_sparse`` declare which backends a
rule supports; a rule without them is rejected on the ``fused*`` /
``sparse`` backends at config-construction time with the full option
list (:func:`resolve_rule_backend`), so the rule × backend matrix
(ROADMAP) is explicit rather than discovered at trace time.
"""

from __future__ import annotations

import abc
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.stdp import STDPParams, pair_gate
from repro.kernels.dispatch import BACKENDS, resolve_backend


class LearningRule(abc.ABC):
    """Protocol every STDP-variant learning rule implements.

    ``name`` is the registry key; ``has_kernel`` marks rules whose state
    layout the fused Pallas kernels consume; ``has_sparse`` marks rules
    that own the event-driven datapath (``backend="sparse"``);
    ``compensate`` is ``None`` when the rule defers to the config's
    compensation flag (the default 'itp' behaviour) or a hard
    ``True``/``False`` override.
    """

    name: str = ""
    has_kernel: bool = False
    has_sparse: bool = False
    compensate: bool | None = None

    # -- state ---------------------------------------------------------
    @abc.abstractmethod
    def init_state(self, n: int, depth: int) -> Any:
        """Fresh timing state for a population of ``n`` neurons."""

    @abc.abstractmethod
    def step(self, state: Any, spikes: jax.Array, *, depth: int) -> Any:
        """Record the current step's spikes (shift-in / counter reset)."""

    # -- readout -------------------------------------------------------
    @abc.abstractmethod
    def readout(self, state: Any) -> jax.Array:
        """Dense ``(rows, n)`` float view of the state for shard_map.

        Row count is rule-specific (``depth`` bitplane rows for history
        rules, one counter row for Δt rules); shards along axis 1.
        """

    def readout_packed(self, state: Any) -> jax.Array:
        """Packed ``(n,)`` uint8 view of the state — one word per neuron.

        For the history rules this is the register word of the paper's
        8-bit register file (``repro.core.history.pack_words``, MSB =
        most recent, depth ≤ 8); for the counter rules it is the
        saturating last-spike counter itself.  Either way it is the
        storage format the rule's fused Pallas kernel consumes and shards
        along axis 0.  Only kernel-backed rules (``has_kernel``)
        implement it — the fused datapaths are unreachable for the others
        (:func:`resolve_rule_backend` rejects them at config time).
        """
        raise NotImplementedError(f"rule {self.name!r} has no packed (kernel) state layout")

    # -- session serialization (the serving "plasticity cache") --------
    # A rule's full timing state round-trips through a small tuple of
    # per-neuron uint8 word planes — the resident per-user state of the
    # serving layer (repro.serve) and the byte count the paper's 1-byte-
    # per-synapse-state claim prices.  ``state_from_words`` must invert
    # ``serve_words`` up to representations with identical continued
    # trajectories (the ring-buffer head is canonicalised away: every
    # readout is rotation-invariant, pinned by tests/test_serve.py).
    # Like the kernel hooks these are called only through the
    # ``UpdatePlan`` session methods (lint rule R8).

    def words_per_neuron(self) -> int:
        """Resident uint8 words per neuron of the serialized state."""
        raise NotImplementedError(f"rule {self.name!r} has no word serialization")

    def serve_words(self, state: Any) -> tuple[jax.Array, ...]:
        """Canonical ``words_per_neuron()``-tuple of ``(n,)`` uint8 words."""
        raise NotImplementedError(f"rule {self.name!r} has no word serialization")

    def state_from_words(self, words: tuple[jax.Array, ...], *, depth: int) -> Any:
        """Rebuild a timing state from :meth:`serve_words` output.

        The rebuilt state's continued trajectory (weights, spikes, and
        re-serialized words) must be bit-identical to the original's.
        """
        raise NotImplementedError(f"rule {self.name!r} has no word serialization")

    # -- fused (kernel) datapath ---------------------------------------
    # Rules with ``has_kernel=True`` own their fused Pallas datapath via
    # these hooks; the engine, sharded engine, and SNN layers dispatch
    # through them instead of importing a kernel package directly.

    def kernel_readout(self, state: Any, *, packed: bool) -> jax.Array:
        """The state view the rule's fused kernel consumes.

        ``packed=True`` selects the per-neuron word layout (``(n,)``
        uint8, axis-0 sharded); ``packed=False`` the dense row layout
        (``(rows, n)`` float32, axis-1 sharded).  Rules whose kernel has
        a single natural operand layout (the counter rules: one uint8
        word per neuron either way) may ignore ``packed``.
        """
        raise NotImplementedError(f"rule {self.name!r} has no fused kernel")

    def kernel_readout_axes(self, *, packed: bool) -> int:
        """ndim of :meth:`kernel_readout`'s result (1 = words, 2 = rows).

        Lets ``shard_map`` callers build partition specs before any state
        exists: a 1-D word readout shards along axis 0, a 2-D row readout
        along axis 1.
        """
        raise NotImplementedError(f"rule {self.name!r} has no fused kernel")

    def fused_update_from_readout(
        self,
        w: jax.Array,
        pre_spike: jax.Array,
        post_spike: jax.Array,
        pre_read: jax.Array,
        post_read: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
        eta: float = 1.0,
        w_min: float = 0.0,
        w_max: float = 1.0,
        interpret: bool = False,
    ) -> jax.Array:
        """Fused clipped weight RMW from :meth:`kernel_readout` views."""
        raise NotImplementedError(f"rule {self.name!r} has no fused kernel")

    def fused_delta_from_readout(
        self,
        pre_spike: jax.Array,
        post_spike: jax.Array,
        pre_read: jax.Array,
        post_read: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
        interpret: bool = False,
    ) -> jax.Array:
        """Raw fused ``(n_pre, n_post)`` Δw (no eta/clip) — the batched
        SNN fc layers vmap this over samples and accumulate."""
        raise NotImplementedError(f"rule {self.name!r} has no fused kernel")

    def conv_delta_from_readout(
        self,
        pre_patches: jax.Array,
        post_spikes: jax.Array,
        pre_read: jax.Array,
        post_read: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
        use_kernel: bool = True,
        interpret: bool = False,
    ) -> jax.Array:
        """Raw ``(K, C)`` conv-layer delta from im2col'd readout views.

        ``pre_read``/``post_read`` are :meth:`kernel_readout` views
        gathered into the im2col patch layout by the caller; unlike the
        dense hooks this one also serves ``use_kernel=False`` (the
        pure-jnp oracle), so conv layers have exactly one dispatch path
        per rule.
        """
        raise NotImplementedError(f"rule {self.name!r} has no fused kernel")

    # -- event-driven (sparse) datapath --------------------------------
    # Rules with ``has_sparse=True`` own the event-driven datapath of
    # ``repro.kernels.itp_sparse``: static-shape event lists gate
    # gather/scatter updates of only the touched weight slices.  The
    # readout views are the same ones :meth:`kernel_readout` produces
    # (packed uint8 words or dense rows) so the sparse backend shares
    # the fused backends' storage format and sharding contract.

    def sparse_update_from_readout(
        self,
        w: jax.Array,
        pre_spike: jax.Array,
        post_spike: jax.Array,
        pre_read: jax.Array,
        post_read: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
        eta: float = 1.0,
        w_min: float = 0.0,
        w_max: float = 1.0,
        max_events: int | None = None,
        pre_events: jax.Array | None = None,
        post_events: jax.Array | None = None,
    ) -> jax.Array:
        """Event-driven clipped weight RMW from readout views.

        ``pre_events``/``post_events`` let shard_map callers ship
        precomputed (tile-translated) event lists; ``None`` extracts
        them from the current-step spikes under ``max_events``.
        """
        raise NotImplementedError(f"rule {self.name!r} has no event-driven datapath")

    def sparse_delta_from_readout(
        self,
        pre_spike: jax.Array,
        post_spike: jax.Array,
        pre_read: jax.Array,
        post_read: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
        max_events: int | None = None,
    ) -> jax.Array:
        """Raw event-driven ``(n_pre, n_post)`` Δw (no eta/clip) — the
        batched SNN fc layers vmap this over samples and accumulate."""
        raise NotImplementedError(f"rule {self.name!r} has no event-driven datapath")

    def sparse_conv_delta_from_readout(
        self,
        pre_patches: jax.Array,
        post_spikes: jax.Array,
        pre_read: jax.Array,
        post_read: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
        max_events: int | None = None,
    ) -> jax.Array:
        """Raw ``(K, C)`` conv delta, im2col on gathered active rows only.

        Same operand layout as :meth:`conv_delta_from_readout` with
        ``use_kernel=False`` (dense bitplane readouts in the im2col
        patch layout); the active-row event list caps at ``max_events``.
        """
        raise NotImplementedError(f"rule {self.name!r} has no event-driven datapath")

    @abc.abstractmethod
    def magnitudes_from_readout(
        self,
        arr: jax.Array,
        amplitude: float,
        tau: float,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
    ) -> jax.Array:
        """Per-neuron Δw magnitude ``(n,)`` from a :meth:`readout` view."""

    def magnitudes(
        self,
        state: Any,
        amplitude: float,
        tau: float,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
    ) -> jax.Array:
        """Per-neuron Δw magnitude ``(n,)`` read from the timing state."""
        arr = self.readout(state)
        return self.magnitudes_from_readout(
            arr, amplitude, tau, depth=depth, pairing=pairing, compensate=compensate
        )

    def last_spikes(self, state: Any) -> jax.Array:
        """``(n,)`` f32 indicator of a spike on the previous step.

        Used by the lateral-inhibition path; rules expose the k=0 view of
        their timing state (1 iff the most recent recorded event was a
        spike).
        """
        raise NotImplementedError

    def check_pairing(self, pairing: str) -> None:
        """Raise ``ValueError`` if the rule cannot express ``pairing``."""
        if pairing not in ("nearest", "all"):
            raise ValueError(f"pairing must be 'nearest' or 'all', got {pairing!r}")

    # -- dense update --------------------------------------------------
    def delta(
        self,
        pre_state: Any,
        post_state: Any,
        pre_spikes: jax.Array,
        post_spikes: jax.Array,
        p: STDPParams,
        *,
        depth: int,
        pairing: str = "nearest",
        compensate: bool = True,
    ) -> jax.Array:
        """Raw pair-gated ``(n_pre, n_post)`` Δw (no eta, clip, quantise).

        Default: rank-1 gated outer product of the per-neuron magnitudes
        — the intrinsic-timing datapath.  Δt-based rules override this
        with their deliberately per-pair formulation so the measured cost
        asymmetry (benchmarks/rule_cost.py) reflects the conventional
        datapath the paper optimises away.
        """
        ltp = self.magnitudes(
            pre_state, p.a_plus, p.tau_plus, depth=depth, pairing=pairing, compensate=compensate
        )
        ltd = self.magnitudes(
            post_state, p.a_minus, p.tau_minus, depth=depth, pairing=pairing, compensate=compensate
        )
        ltp_en, ltd_en = pair_gate(pre_spikes[:, None], post_spikes[None, :])
        return ltp_en * ltp[:, None] - ltd_en * ltd[None, :]


# ---------------------------------------------------------------------------
# Generic rank-1 backend adapters
# ---------------------------------------------------------------------------

# Unit STDP params for the magnitude-plane adapters below: with a single
# depth-1 plane the kernels' po2 weighting is exp2(0) = 1.0 for any tau,
# so `po2 @ plane` returns the plane itself and the amplitudes must not
# be applied twice.
_UNIT_PARAMS = STDPParams(a_plus=1.0, a_minus=1.0)


class Rank1Rule(LearningRule):
    """Slim-protocol base: every backend from five rule-owned methods.

    For any rule whose dense update is the pair-gated rank-1 form

        ``dw = gate_ltp * ltp[:, None] - gate_ltd * ltd[None, :]``

    with per-neuron magnitudes ``ltp``/``ltd`` read from the state, the
    whole backend hook seam is derivable — so this base implements it
    once, generically, and a subclass only writes the slim protocol
    (``init_state`` / ``step`` / ``readout`` /
    ``magnitudes_from_readout`` / ``last_spikes``).

    The trick that makes the adapters exact with **zero new kernel
    code**: the existing intrinsic-timing datapaths all compute their
    per-neuron magnitudes as ``po2_weights(depth, tau) @ bitplanes``
    before the shared XOR-gate/outer-product/scatter machinery.  Feeding
    them the rule's already-computed magnitude vector as a single
    depth-1 "bitplane" with unit amplitudes (``po2_weights(1, tau) =
    [exp2(0)] = [1.0]`` for any tau, compensated or not) makes that dot
    product the identity: ``1.0 * m == m`` exactly in float32.  Pairing
    is forced to ``"all"`` inside the adapters because the
    nearest-spike cumsum mask assumes binary planes — the rule's own
    ``magnitudes_from_readout`` already owns whatever pairing semantics
    it supports.

    Subclasses default to the full backend column (``has_kernel`` and
    ``has_sparse`` both True); opt out by overriding the flags and the
    config-construction validator rejects the missing cells with the
    usual option listing.
    """

    has_kernel: bool = True
    has_sparse: bool = True

    # -- readout views --------------------------------------------------

    def kernel_readout(self, state: Any, *, packed: bool) -> jax.Array:
        """Generic rules have one layout — the dense readout rows.

        ``packed`` is a storage-format optimisation of the built-in
        families' register words; a generic rule's rows are its storage
        format, so the flag is accepted (the plan passes it uniformly)
        and ignored.
        """
        del packed
        return self.readout(state)

    def kernel_readout_axes(self, *, packed: bool) -> int:
        del packed
        return 2

    def readout_packed(self, state: Any) -> jax.Array:
        raise NotImplementedError(
            f"rule {self.name!r} has no packed word layout: generic "
            f"rank-1 rules ship their dense readout rows to every backend"
        )

    def _readout_magnitudes(
        self,
        arr: jax.Array,
        amplitude: float,
        tau: float,
        *,
        depth: int,
        pairing: str,
        compensate: bool,
    ) -> jax.Array:
        """``magnitudes_from_readout`` over views with trailing dims.

        The conv adapters receive ``(rows, M, K)`` patch views; flatten
        the trailing dims to the ``(rows, n)`` contract, read, reshape
        back.
        """
        rows = arr.shape[0]
        flat = arr.reshape(rows, -1)
        m = self.magnitudes_from_readout(
            flat, amplitude, tau, depth=depth, pairing=pairing, compensate=compensate
        )
        return m.reshape(arr.shape[1:])

    def _magnitude_pair(
        self, pre_read, post_read, p, *, depth, pairing, compensate
    ) -> tuple[jax.Array, jax.Array]:
        ltp = self._readout_magnitudes(
            pre_read, p.a_plus, p.tau_plus, depth=depth, pairing=pairing, compensate=compensate
        )
        ltd = self._readout_magnitudes(
            post_read, p.a_minus, p.tau_minus, depth=depth, pairing=pairing, compensate=compensate
        )
        return ltp, ltd

    # -- fused (kernel) datapath ---------------------------------------

    def fused_update_from_readout(
        self,
        w,
        pre_spike,
        post_spike,
        pre_read,
        post_read,
        p,
        *,
        depth,
        pairing="nearest",
        compensate=True,
        eta=1.0,
        w_min=0.0,
        w_max=1.0,
        interpret=False,
    ):
        from repro.kernels.itp_stdp.ops import weight_update_depth_major

        ltp, ltd = self._magnitude_pair(
            pre_read, post_read, p, depth=depth, pairing=pairing, compensate=compensate
        )
        return weight_update_depth_major(
            w,
            pre_spike,
            post_spike,
            ltp[None, :],
            ltd[None, :],
            _UNIT_PARAMS,
            pairing="all",
            compensate=False,
            eta=eta,
            w_min=w_min,
            w_max=w_max,
            interpret=interpret,
        )

    def fused_delta_from_readout(
        self,
        pre_spike,
        post_spike,
        pre_read,
        post_read,
        p,
        *,
        depth,
        pairing="nearest",
        compensate=True,
        interpret=False,
    ):
        from repro.kernels.itp_stdp.ops import synapse_delta

        ltp, ltd = self._magnitude_pair(
            pre_read, post_read, p, depth=depth, pairing=pairing, compensate=compensate
        )
        return synapse_delta(
            pre_spike,
            post_spike,
            ltp[None, :],
            ltd[None, :],
            _UNIT_PARAMS,
            pairing="all",
            compensate=False,
            interpret=interpret,
        )

    def conv_delta_from_readout(
        self,
        pre_patches,
        post_spikes,
        pre_read,
        post_read,
        p,
        *,
        depth,
        pairing="nearest",
        compensate=True,
        use_kernel=True,
        interpret=False,
    ):
        from repro.kernels.itp_stdp_conv.ops import conv_synapse_delta

        ltp, ltd = self._magnitude_pair(
            pre_read, post_read, p, depth=depth, pairing=pairing, compensate=compensate
        )
        return conv_synapse_delta(
            pre_patches,
            post_spikes,
            ltp[None],
            ltd[None],
            _UNIT_PARAMS,
            pairing="all",
            compensate=False,
            use_kernel=use_kernel,
            interpret=interpret,
        )

    # -- event-driven (sparse) datapath --------------------------------

    def sparse_update_from_readout(
        self,
        w,
        pre_spike,
        post_spike,
        pre_read,
        post_read,
        p,
        *,
        depth,
        pairing="nearest",
        compensate=True,
        eta=1.0,
        w_min=0.0,
        w_max=1.0,
        max_events=None,
        pre_events=None,
        post_events=None,
    ):
        from repro.kernels.itp_sparse.ops import sparse_weight_update

        ltp, ltd = self._magnitude_pair(
            pre_read, post_read, p, depth=depth, pairing=pairing, compensate=compensate
        )
        return sparse_weight_update(
            w,
            pre_spike,
            post_spike,
            ltp,
            ltd,
            eta=eta,
            w_min=w_min,
            w_max=w_max,
            max_events=max_events,
            pre_events=pre_events,
            post_events=post_events,
        )

    def sparse_delta_from_readout(
        self,
        pre_spike,
        post_spike,
        pre_read,
        post_read,
        p,
        *,
        depth,
        pairing="nearest",
        compensate=True,
        max_events=None,
    ):
        from repro.kernels.itp_sparse.ops import sparse_synapse_delta

        ltp, ltd = self._magnitude_pair(
            pre_read, post_read, p, depth=depth, pairing=pairing, compensate=compensate
        )
        return sparse_synapse_delta(pre_spike, post_spike, ltp, ltd, max_events=max_events)

    def sparse_conv_delta_from_readout(
        self,
        pre_patches,
        post_spikes,
        pre_read,
        post_read,
        p,
        *,
        depth,
        pairing="nearest",
        compensate=True,
        max_events=None,
    ):
        from repro.kernels.itp_sparse.ops import sparse_conv_delta

        ltp, ltd = self._magnitude_pair(
            pre_read, post_read, p, depth=depth, pairing=pairing, compensate=compensate
        )
        po2_one = jnp.ones((1,), jnp.float32)
        return sparse_conv_delta(
            pre_patches,
            post_spikes,
            ltp[None],
            ltd[None],
            po2_one,
            po2_one,
            nearest=False,
            max_events=max_events,
        )


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

RULES: dict[str, LearningRule] = {}


def register_rule(rule: LearningRule) -> LearningRule:
    """Add ``rule`` to the registry (keyed by ``rule.name``)."""
    if not rule.name:
        raise ValueError("learning rule must carry a non-empty name")
    RULES[rule.name] = rule
    return rule


def rule_names() -> tuple[str, ...]:
    return tuple(sorted(RULES))


def get_rule(name: str) -> LearningRule:
    """Look up a registered rule; unknown names list the valid options."""
    try:
        return RULES[name]
    except KeyError as e:
        raise ValueError(f"unknown learning rule {name!r}; have {rule_names()}") from e


def kernel_rule_names() -> tuple[str, ...]:
    return tuple(sorted(n for n, r in RULES.items() if r.has_kernel))


def sparse_rule_names() -> tuple[str, ...]:
    return tuple(sorted(n for n, r in RULES.items() if r.has_sparse))


def validate_update_config(
    *,
    rule: str,
    backend: str,
    pairing: str,
    max_events: int | None,
) -> LearningRule:
    """Single cross-field validator shared by ``EngineConfig`` and ``SNNConfig``.

    Every constraint the two configs share lives here exactly once, so the
    error messages (and their valid-option listings) cannot drift between
    them: unknown rule/backend names list the registry options, kernel-less
    rules reject the ``fused*`` backends, rules without event hooks reject
    ``sparse``, counter rules reject ``pairing="all"``, and ``max_events``
    must be a positive cap or ``None``.  Returns the resolved rule so
    callers avoid a second registry lookup.
    """
    resolved = get_rule(rule)
    resolve_rule_backend(resolved, backend)
    resolved.check_pairing(pairing)
    if max_events is not None and max_events < 1:
        raise ValueError(
            f"max_events must be a positive event-list cap or None "
            f"(uncapped), got {max_events}"
        )
    return resolved


def resolve_rule_backend(rule: str | LearningRule, backend: str) -> tuple[bool, bool]:
    """Validate a (rule, backend) cell and map it to (use_kernel, interpret).

    Unknown rule or backend names raise ``ValueError`` listing the valid
    options; a kernel-less rule on a ``fused*`` backend — or a rule
    without event hooks on the ``sparse`` backend — is rejected with the
    actionable alternatives (the ROADMAP rule × backend matrix), never
    at trace time.
    """
    if isinstance(rule, str):
        rule = get_rule(rule)
    use_kernel, interpret = resolve_backend(backend)
    if use_kernel and not rule.has_kernel:
        raise ValueError(
            f"rule {rule.name!r} has no fused kernel: backend {backend!r} is "
            f"only available for the kernel-backed rules "
            f"{kernel_rule_names()}; use backend='reference' for "
            f"{rule.name!r} (valid backends: {BACKENDS})"
        )
    if backend == "sparse" and not rule.has_sparse:
        raise ValueError(
            f"rule {rule.name!r} has no event-driven datapath: backend "
            f"'sparse' is only available for the event-hook rules "
            f"{sparse_rule_names()}; use backend='reference' for "
            f"{rule.name!r} (valid backends: {BACKENDS})"
        )
    return use_kernel, interpret
