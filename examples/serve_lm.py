"""Serve a smoke LM with continuous batching and int8 KV cache.

Submits a mixed batch of requests to the slot-based server (the serving
analogue of the learning engine's time-multiplexed neuron pipeline) and
compares bf16 vs int8 KV-cache serving.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch qwen2-1.5b]
"""
import argparse
import time

import jax

from repro.configs import get_smoke_config
from repro.models import transformer
from repro.serve import Request, ServeConfig, Server


def serve_once(params, cfg, kv_dtype: str, n_requests: int = 6,
               slots: int = 3, max_new: int = 12) -> float:
    scfg = ServeConfig(max_tokens=128, batch=slots, kv_dtype=kv_dtype)
    server = Server(params, cfg, scfg)
    key = jax.random.PRNGKey(1)
    for i in range(n_requests):
        key, sub = jax.random.split(key)
        plen = int(jax.random.randint(sub, (), 3, 10))
        prompt = [int(t) for t in
                  jax.random.randint(sub, (plen,), 0, cfg.vocab_size)]
        server.submit(Request(uid=i, prompt=prompt, max_new=max_new))
    t0 = time.time()
    done = server.run(max_steps=400)
    dt = time.time() - t0
    n_tok = sum(len(r.out) for r in done)
    print(f"  kv={kv_dtype:8s}: {len(done)}/{n_requests} requests, "
          f"{n_tok} tokens in {dt:.1f}s ({n_tok / dt:.1f} tok/s)")
    return dt


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-1.5b")
    args = ap.parse_args()
    cfg = get_smoke_config(args.arch)
    params = transformer.init_model(jax.random.PRNGKey(0), cfg)
    print(f"serving {cfg.name} with continuous batching:")
    serve_once(params, cfg, "bfloat16")
    serve_once(params, cfg, "int8")
    print("int8 KV halves cache HBM at 512k-token contexts "
          "(see DESIGN.md §6 and tests/test_models.py int8 bound)")


if __name__ == "__main__":
    main()
