"""Train an assigned LM architecture (smoke config) with the full
production loop: jitted train step, async checkpointing, failure-injected
restart — plus the beyond-paper ITP-AdamW po2-quantised optimizer.

Run:  PYTHONPATH=src python examples/train_lm.py [--arch qwen3-0.6b]
      [--po2-update]     # the paper's quantiser applied to AdamW updates
"""
import argparse
import subprocess
import sys


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-0.6b")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--po2-update", action="store_true")
    args = ap.parse_args()

    cmd = [sys.executable, "-m", "repro.launch.train",
           "--arch", args.arch, "--smoke",
           "--steps", str(args.steps), "--batch", "4", "--seq", "64",
           "--ckpt-every", "20", "--ckpt-dir", "/tmp/repro_lm_ckpt",
           "--inject-failure-at", str(args.steps // 2),
           "--log-every", "10"]
    if args.po2_update:
        cmd.append("--po2-update")
    print("launching:", " ".join(cmd))
    raise SystemExit(subprocess.run(cmd).returncode)


if __name__ == "__main__":
    main()
