"""End-to-end driver: train one of the paper's SNNs with ITP-STDP to
classification accuracy.

Epochs of unsupervised STDP over rate-coded synthetic stand-in data with
intra-layer competition (soft lateral inhibition / ``--hard-wta``) and
adaptive-threshold homeostasis (``--theta-plus`` / ``--theta-tau``), each
followed by the label-assignment evaluation of
``repro.train.stdp_trainer``: every excitatory neuron is assigned to its
max-response class on a held-out pass, then samples classify by the
assigned-population vote — the fully unsupervised Table II protocol.

Run:  PYTHONPATH=src python examples/train_snn.py \
          [--net 2layer-snn|6layer-dcsnn|5layer-csnn] \
          [--rule itp|itp_nocomp|exact|linear|imstdp] \
          [--backend reference|fused|fused_interpret|sparse] \
          [--epochs 5] [--theta-plus 0.02] [--hard-wta]

Every flag is declared once in ``repro.launch.cli`` and shared verbatim
with ``python -m repro.launch.train --snn`` — the two entry points build
the same ``SNNConfig`` / ``TrainerConfig`` pair.  ``--rule`` selects the
learning rule from the ``repro.plasticity`` registry (the paper's
Table II comparison axis); every rule runs on every backend it supports,
so the accuracy comparison is kernel-vs-kernel.
"""
import argparse

from repro.launch import cli
from repro.models import snn
from repro.train.stdp_trainer import train_to_accuracy


def main():
    ap = argparse.ArgumentParser()
    cli.add_net_flag(ap, "--net")
    cli.add_update_flags(ap)
    cli.add_train_flags(ap)
    args = ap.parse_args()

    cfg = cli.snn_config_from_args(args)
    tcfg = cli.trainer_config_from_args(args)
    sampler, n_classes = cli.sampler_for(args.net)

    print(f"training {cfg.name} ({'×'.join(str(d) for d in cfg.input_shape)}"
          f"→{snn.feature_size(cfg)}) with rule={cfg.rule!r} "
          f"backend={cfg.backend!r}: {tcfg.epochs} epochs × "
          f"{tcfg.batches_per_epoch} batches × {tcfg.t_steps} steps "
          f"(θ+ {cfg.theta_plus}, hard WTA {cfg.hard_wta})")
    result = train_to_accuracy(cfg, sampler, n_classes, tcfg, verbose=True)
    print(f"STDP training done in {result['train_seconds']:.1f}s")
    print(f"assignment accuracy: {result['final_accuracy']:.3f} "
          f"(chance {result['chance']:.3f}) — net={cfg.name!r} "
          f"rule={cfg.rule!r} backend={cfg.backend!r}")


if __name__ == "__main__":
    main()
