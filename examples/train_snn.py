"""End-to-end driver: train the paper's 2-layer SNN with ITP-STDP.

A few hundred unsupervised STDP steps over rate-coded synthetic digits
(the paper's MNIST protocol with the offline stand-in dataset), then a
ridge readout on the frozen spike-count features — the Table II pipeline.

Run:  PYTHONPATH=src python examples/train_snn.py [--rule itp|exact|itp_nocomp]
      (--steps 300 ≈ 300 simulation steps = 10 batches × 30-step rasters)
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.data import Prefetcher, encode_batch, spike_stream, synthetic_digits
from repro.kernels.itp_stdp.ops import BACKENDS
from repro.models import snn


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rule", default="itp",
                    choices=("exact", "itp", "itp_nocomp"))
    ap.add_argument("--backend", default="reference", choices=BACKENDS,
                    help="weight-update datapath: pure-jnp reference or the "
                         "fused Pallas kernel (interpret mode runs it on CPU)")
    ap.add_argument("--steps", type=int, default=300,
                    help="total simulation steps of STDP training")
    ap.add_argument("--t-raster", type=int, default=30)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=100)
    args = ap.parse_args()

    cfg = snn.mnist_2layer(args.rule, n_hidden=args.hidden,
                           backend=args.backend)
    key = jax.random.PRNGKey(0)
    state = snn.init_snn(key, cfg, args.batch)
    n_batches = max(args.steps // args.t_raster, 1)

    print(f"training 2-layer SNN ({784}→{args.hidden}) with rule="
          f"{args.rule!r} backend={args.backend!r}: "
          f"{n_batches} batches × {args.t_raster} steps")
    stream = Prefetcher(spike_stream(
        key, lambda k, n: synthetic_digits(k, n),
        batch=args.batch, t_steps=args.t_raster, n_steps=n_batches))

    t0 = time.time()
    for i, batch in enumerate(stream):
        state, counts = snn.run_snn(state, batch["spikes"], cfg, train=True)
        state = snn.reset_dynamics(state, cfg, args.batch)
        if i % 2 == 0:
            w = state.weights[0]
            print(f"  batch {i:3d}: mean rate "
                  f"{float(counts.mean()) / args.t_raster:.3f}  "
                  f"w∈[{float(w.min()):.2f},{float(w.max()):.2f}] "
                  f"μ={float(w.mean()):.3f}")
    print(f"STDP training done in {time.time() - t0:.1f}s")

    # frozen-feature readout (Table II protocol)
    def features(n, seed):
        fs, ls = [], []
        kk = jax.random.PRNGKey(seed)
        s = state
        for _ in range(n // args.batch):
            kk, kd, ke = jax.random.split(kk, 3)
            x, y = synthetic_digits(kd, args.batch)
            s = snn.reset_dynamics(s, cfg, args.batch)
            s, c = snn.run_snn(s, encode_batch(ke, x, args.t_raster), cfg,
                               train=False)
            fs.append(c)
            ls.append(y)
        return jnp.concatenate(fs), jnp.concatenate(ls)

    Xtr, ytr = features(96, 10)
    Xte, yte = features(64, 20)
    W = snn.fit_readout(Xtr, ytr, 10)
    acc = snn.readout_accuracy(W, Xte, yte)
    print(f"readout accuracy: {acc:.3f} (chance 0.100) — rule={args.rule!r}")


if __name__ == "__main__":
    main()
