"""End-to-end driver: train one of the paper's SNNs with ITP-STDP.

A few hundred unsupervised STDP steps over rate-coded synthetic data
(the paper's protocol with the offline stand-in datasets), then a ridge
readout on the frozen spike-count features — the Table II pipeline.
``--net`` selects the network: the 2-layer fc SNN, the 6-layer conv DCSNN
or the 5-layer conv CSNN; ``--backend`` selects the weight-update
datapath for every layer kind (the conv nets exercise the im2col-fused
conv kernel, the fc layers the dense engine kernel).

Run:  PYTHONPATH=src python examples/train_snn.py \
          [--net 2layer-snn|6layer-dcsnn|5layer-csnn] \
          [--rule itp|itp_nocomp|exact|linear|imstdp] \
          [--backend reference|fused|fused_interpret|sparse]
      (--steps 300 ≈ 300 simulation steps = 10 batches × 30-step rasters)

``--rule`` selects the learning rule from the ``repro.plasticity``
registry — the paper's Table II comparison axis.  Every rule runs on
every fused* backend: the counter rules (exact/linear/imstdp) ride the
fused explicit-Δt kernels of ``repro.kernels.itp_counter``, so the rule
comparison is kernel-vs-kernel.  ``--backend sparse`` selects the
event-driven datapath for the history rules (``--max-events`` caps the
static event-list length per side).
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import plasticity
from repro.data import (Prefetcher, encode_batch, spike_stream,
                        synthetic_digits, synthetic_fashion, synthetic_fault)
from repro.kernels.dispatch import BACKENDS
from repro.models import snn

SAMPLERS = {
    "2layer-snn": (lambda k, n: synthetic_digits(k, n), 10),
    "6layer-dcsnn": (lambda k, n: synthetic_fashion(k, n), 10),
    "5layer-csnn": (lambda k, n: synthetic_fault(k, n), 4),
}
assert set(SAMPLERS) == set(snn.PAPER_NETWORKS), \
    "SAMPLERS must cover every network in snn.PAPER_NETWORKS"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--net", default="2layer-snn", choices=tuple(SAMPLERS),
                    help="which of the paper's three networks to train")
    ap.add_argument("--rule", default="itp",
                    choices=plasticity.rule_names(),
                    help="learning rule (paper Table II axis); every rule "
                         "runs on every --backend")
    ap.add_argument("--backend", default="reference", choices=BACKENDS,
                    help="weight-update datapath: pure-jnp reference, the "
                         "fused Pallas kernels (interpret mode runs them on "
                         "CPU), or the event-driven sparse path; applies to "
                         "fc and conv layers alike")
    ap.add_argument("--max-events", type=int, default=None,
                    help="sparse backend: static event-list cap per side "
                         "(default: uncapped)")
    ap.add_argument("--steps", type=int, default=300,
                    help="total simulation steps of STDP training")
    ap.add_argument("--t-raster", type=int, default=30)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--hidden", type=int, default=100,
                    help="hidden width (2layer-snn only)")
    args = ap.parse_args()

    maker = snn.PAPER_NETWORKS[args.net]
    kw = {"n_hidden": args.hidden} if args.net == "2layer-snn" else {}
    cfg = maker(args.rule, backend=args.backend,
                max_events=args.max_events, **kw)
    sampler, n_classes = SAMPLERS[args.net]
    key = jax.random.PRNGKey(0)
    state = snn.init_snn(key, cfg, args.batch)
    n_batches = max(args.steps // args.t_raster, 1)

    print(f"training {cfg.name} ({'×'.join(str(d) for d in cfg.input_shape)}"
          f"→{snn.feature_size(cfg)}) with rule={args.rule!r} "
          f"backend={args.backend!r}: "
          f"{n_batches} batches × {args.t_raster} steps")
    stream = Prefetcher(spike_stream(
        key, sampler,
        batch=args.batch, t_steps=args.t_raster, n_steps=n_batches))

    t0 = time.time()
    for i, batch in enumerate(stream):
        state, counts = snn.run_snn(state, batch["spikes"], cfg, train=True)
        state = snn.reset_dynamics(state, cfg, args.batch)
        if i % 2 == 0:
            w = state.weights[0]
            print(f"  batch {i:3d}: mean rate "
                  f"{float(counts.mean()) / args.t_raster:.3f}  "
                  f"w∈[{float(w.min()):.2f},{float(w.max()):.2f}] "
                  f"μ={float(w.mean()):.3f}")
    print(f"STDP training done in {time.time() - t0:.1f}s")

    # frozen-feature readout (Table II protocol)
    def features(n, seed):
        fs, ls = [], []
        kk = jax.random.PRNGKey(seed)
        s = state
        for _ in range(n // args.batch):
            kk, kd, ke = jax.random.split(kk, 3)
            x, y = sampler(kd, args.batch)
            s = snn.reset_dynamics(s, cfg, args.batch)
            s, c = snn.run_snn(s, encode_batch(ke, x, args.t_raster), cfg,
                               train=False)
            fs.append(c)
            ls.append(y)
        return jnp.concatenate(fs), jnp.concatenate(ls)

    Xtr, ytr = features(96, 10)
    Xte, yte = features(64, 20)
    W = snn.fit_readout(Xtr, ytr, n_classes)
    acc = snn.readout_accuracy(W, Xte, yte)
    print(f"readout accuracy: {acc:.3f} (chance {1.0 / n_classes:.3f}) — "
          f"net={args.net!r} rule={args.rule!r} backend={args.backend!r}")


if __name__ == "__main__":
    main()
