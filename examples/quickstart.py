"""Quickstart: the paper's 4×4 prototype ITP-STDP learning engine.

Builds the prototype engine (§III-B, Table V row 1), drives it with a
Poisson spike train, and demonstrates the paper's two core claims:

  1. intrinsic timing — the weight update is read directly off the
     spike-history register (no Δt computation, no exponential);
  2. compensation — with τ' = τ·ln2 the po2 rule is numerically identical
     to exact base-e STDP.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core.drift import DriftParams, update_curve_rmse
from repro.core.engine import EngineConfig, init_engine, run_engine
from repro.core.history import init_history, push, registers_depth_major
from repro.core.stdp import magnitudes_depth_major

key = jax.random.PRNGKey(0)

# --- 1. the 4×4 prototype engine -------------------------------------------
cfg = EngineConfig(n_pre=4, n_post=4, depth=7, pairing="nearest")
state = init_engine(key, cfg)
print("prototype engine: 4 pre × 4 post, history depth 7, 8-bit weights")
print("initial weights:\n", state.w)

train = jax.random.bernoulli(key, 0.35, (200, 4))     # 200-step Poisson raster
state, post_spikes = run_engine(state, train, cfg)
print(f"\nafter 200 steps: {int(post_spikes.sum())} postsynaptic spikes")
print("learned weights:\n", state.w)

# --- 2. 'reading the register IS the update' --------------------------------
hist = init_history(4, depth=7)
for t, row in enumerate([[1, 0, 0, 1], [0, 0, 1, 0], [0, 1, 0, 0]]):
    hist = push(hist, jnp.asarray(row, jnp.uint8))
regs = registers_depth_major(hist)
print("\nspike-history registers (k=0 row = most recent):\n", regs)
mags = magnitudes_depth_major(regs, 1.0, 4.0, pairing="nearest")
print("Δw magnitudes read straight off the registers:", mags)
print("  (= A·2^(-k*/τ') where k* is each neuron's most recent spike)")

# --- 3. the compensation equivalence (eq. 18) --------------------------------
p = DriftParams()
print("\nupdate-curve RMSE vs exact STDP:")
print(f"  ITP w/o compensation: {update_curve_rmse(p):.6f}  "
      f"(paper: 0.094753)")
print(f"  ITP with τ·ln2 comp.: {update_curve_rmse(p, 'exact', 'itp'):.2e}  "
      f"(paper: exactly 0)")

# --- 4. pluggable learning rules (EngineConfig.rule) -------------------------
# The same engine runs the conventional counter-based exact-STDP baseline
# (per-pair Δt + base-e exponential — what the paper optimises away) by
# swapping the rule; compensated ITP reproduces its trajectory exactly.
# The full registry (itp, itp_nocomp, exact, linear, imstdp) is also on the
# CLI:  python examples/train_snn.py --rule exact
#       python -m repro.launch.train --engine --rule exact
cfg_exact = EngineConfig(n_pre=4, n_post=4, depth=7, rule="exact")
state_exact, _ = run_engine(init_engine(key, cfg_exact), train, cfg_exact)
state_itp, _ = run_engine(init_engine(key, cfg), train, cfg)
drift = float(jnp.abs(state_exact.w - state_itp.w).max())
print(f"\nrule='exact' (counter Δt baseline) vs rule='itp': "
      f"max |Δw| = {drift:.2e}  (identical trajectories — eq. 18 at the "
      f"engine level)")
