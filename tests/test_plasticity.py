"""Unified LearningRule API: registry, rule × backend matrix, trajectory
pins, and the CounterEngine deprecation shims.

The load-bearing contracts:

  * ``rule="itp"`` through the new API is bit-identical to the
    pre-redesign engine datapath (the manual loop below replicates the
    old ``engine_step`` ops exactly — array_equal, not allclose);
  * ``rule="exact"`` (the counter-based baseline folded into the rule
    registry) reproduces compensated ITP trajectories — the paper's
    eq. 18 equivalence at the system level;
  * invalid rule/backend names and kernel-less rule + fused* cells fail
    at config-construction time with the valid options listed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import plasticity
from repro.core import history as H
from repro.core.engine import EngineConfig, init_engine, run_engine
from repro.core.lif import lif_step
from repro.core.stdp import magnitudes_depth_major, pair_gate
from repro.models import snn

T_STEPS = 40


# ---------------------------------------------------------------------------
# Registry + error paths (config-construction time)
# ---------------------------------------------------------------------------

def test_registry_contents():
    names = plasticity.rule_names()
    assert set(names) >= {"itp", "itp_nocomp", "exact", "linear", "imstdp",
                          "mstdp"}
    # every registered rule is kernel-backed since the itp_counter package
    # closed the counter side of the rule × backend matrix (PR 5)
    assert set(plasticity.kernel_rule_names()) == set(names)
    assert plasticity.get_rule("itp").has_kernel
    assert plasticity.get_rule("exact").has_kernel


def test_unknown_rule_lists_options():
    with pytest.raises(ValueError, match="unknown learning rule.*itp"):
        EngineConfig(rule="hebbian")
    with pytest.raises(ValueError, match="unknown learning rule.*itp"):
        snn.mnist_2layer("hebbian", n_hidden=8)


def test_unknown_backend_lists_options():
    with pytest.raises(ValueError, match="unknown backend.*reference"):
        EngineConfig(backend="cuda")
    with pytest.raises(ValueError, match="unknown backend.*reference"):
        snn.mnist_2layer("itp", n_hidden=8, backend="cuda")


@pytest.mark.parametrize("backend", ["fused", "fused_interpret"])
@pytest.mark.parametrize("rule", ["exact", "linear", "imstdp"])
def test_counter_rule_fused_cells_construct(rule, backend):
    """The former ValueError cells of the rule × backend matrix are open:
    counter rules are kernel-backed (repro.kernels.itp_counter)."""
    assert EngineConfig(rule=rule, backend=backend).backend == backend
    assert snn.mnist_2layer(rule, n_hidden=8, backend=backend).rule == rule


def test_kernel_less_rule_rejects_fused():
    """A rule without a kernel still fails fast on the fused* backends with
    the actionable alternatives (the config-construction-time contract)."""
    class NoKernelRule(plasticity.CounterRule):
        pass

    rule = NoKernelRule(name="nokernel", has_kernel=False)
    with pytest.raises(ValueError, match="no fused kernel.*reference"):
        plasticity.resolve_rule_backend(rule, "fused_interpret")
    assert plasticity.resolve_rule_backend(rule, "reference") == (False, False)


def test_counter_rule_rejects_all_to_all():
    with pytest.raises(ValueError, match="nearest"):
        EngineConfig(rule="exact", pairing="all")


def test_sparse_rule_registry():
    """Only rules with event hooks open the sparse backend column: the
    history family plus the Rank1Rule-derived mstdp."""
    assert set(plasticity.sparse_rule_names()) == {"itp", "itp_nocomp", "mstdp"}
    assert plasticity.get_rule("itp").has_sparse
    assert not plasticity.get_rule("exact").has_sparse
    # sparse maps to the non-Pallas path: consumers branch explicitly
    rule = plasticity.get_rule("itp")
    assert plasticity.resolve_rule_backend(rule, "sparse") == (False, False)


@pytest.mark.parametrize("rule", ["exact", "linear", "imstdp"])
def test_counter_rule_rejects_sparse_at_construction(rule):
    """A rule without event hooks fails at config construction — never at
    trace time — and the message lists the valid alternatives."""
    with pytest.raises(ValueError, match="event-driven.*itp.*reference"):
        EngineConfig(rule=rule, backend="sparse")
    with pytest.raises(ValueError, match="event-driven.*itp.*reference"):
        snn.mnist_2layer(rule, n_hidden=8, backend="sparse")
    with pytest.raises(ValueError, match="event-driven"):
        plasticity.resolve_rule_backend(plasticity.get_rule(rule), "sparse")


def test_sparse_cells_construct_for_history_rules():
    for rule in plasticity.sparse_rule_names():
        assert EngineConfig(rule=rule, backend="sparse").backend == "sparse"
        assert snn.mnist_2layer(rule, n_hidden=8,
                                backend="sparse").backend == "sparse"


def test_launcher_cli_rejects_bad_rule():
    """argparse surfaces the registry as --rule choices."""
    import subprocess
    import sys
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--engine",
         "--rule", "hebbian"],
        capture_output=True, text=True,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert r.returncode != 0
    assert "--rule" in r.stderr and "itp" in r.stderr


def test_history_rule_last_spikes_reads_newest_bit_without_relayout(key):
    """The hot-path newest-spike readout is planes[head] directly and must
    equal the k=0 column of the full (N, depth) register materialisation —
    for every ring-buffer head position, including pre-wrap and post-wrap."""
    rule = plasticity.get_rule("itp")
    n, depth = 13, 7
    state = rule.init_state(n, depth)
    np.testing.assert_array_equal(np.asarray(rule.last_spikes(state)),
                                  np.zeros(n, np.float32))
    for t in range(2 * depth + 3):                # wraps the ring twice
        spikes = jax.random.bernoulli(jax.random.fold_in(key, t), 0.4, (n,))
        state = rule.step(state, spikes, depth=depth)
        want = np.asarray(H.as_register(state))[:, 0].astype(np.float32)
        got = np.asarray(rule.last_spikes(state))
        np.testing.assert_array_equal(got, want)
        np.testing.assert_array_equal(got, np.asarray(spikes, np.float32))


def test_history_rule_packed_readout_matches_pack_words(key):
    """readout_packed is the registry view of pack_words for the history
    rules; for the counter rules it is the saturating counter itself as a
    uint8 word — the same shape/sharding contract either way."""
    rule = plasticity.get_rule("itp")
    state = rule.init_state(9, 7)
    for t in range(5):
        state = rule.step(state, jax.random.bernoulli(
            jax.random.fold_in(key, t), 0.5, (9,)), depth=7)
    np.testing.assert_array_equal(np.asarray(rule.readout_packed(state)),
                                  np.asarray(H.pack_words(state)))
    exact = plasticity.get_rule("exact")
    cstate = exact.init_state(4, 7)
    cstate = exact.step(cstate, jnp.array([1, 0, 0, 1]), depth=7)
    words = exact.readout_packed(cstate)
    assert words.dtype == jnp.uint8 and words.shape == (4,)
    np.testing.assert_array_equal(np.asarray(words),
                                  np.asarray(cstate, np.uint8))


# ---------------------------------------------------------------------------
# Trajectory pins
# ---------------------------------------------------------------------------

def _pre_redesign_reference_step(state, pre_spikes, cfg):
    """The pre-redesign engine_step ops, verbatim (reference backend)."""
    pre_spikes = jnp.asarray(pre_spikes)
    i_in = pre_spikes.astype(jnp.float32) @ state.w
    neurons, post_spikes = lif_step(state.neurons, i_in, cfg.lif)
    ltp_mag = magnitudes_depth_major(
        H.registers_depth_major(state.pre_hist), cfg.stdp.a_plus,
        cfg.stdp.tau_plus, pairing=cfg.pairing, compensate=cfg.compensate)
    ltd_mag = magnitudes_depth_major(
        H.registers_depth_major(state.post_hist), cfg.stdp.a_minus,
        cfg.stdp.tau_minus, pairing=cfg.pairing, compensate=cfg.compensate)
    ltp_en, ltd_en = pair_gate(pre_spikes[:, None], post_spikes[None, :])
    dw = ltp_en * ltp_mag[:, None] - ltd_en * ltd_mag[None, :]
    w = jnp.clip(state.w + cfg.eta * dw, cfg.w_min, cfg.w_max)
    pre_hist = H.push(state.pre_hist, pre_spikes)
    post_hist = H.push(state.post_hist, post_spikes)
    return type(state)(w, pre_hist, post_hist, neurons), post_spikes


@pytest.mark.parametrize("pairing", ["nearest", "all"])
def test_itp_through_rule_api_bit_identical_to_pre_redesign(key, pairing):
    cfg = EngineConfig(n_pre=24, n_post=16, eta=0.25, pairing=pairing)
    state = init_engine(key, cfg)
    train = jax.random.bernoulli(key, 0.35, (T_STEPS, cfg.n_pre))
    s_new, post_new = run_engine(state, train, cfg)
    s_old = state
    posts = []
    for t in range(T_STEPS):
        s_old, post = _pre_redesign_reference_step(s_old, train[t], cfg)
        posts.append(np.asarray(post))
    np.testing.assert_array_equal(np.asarray(s_new.w), np.asarray(s_old.w))
    np.testing.assert_array_equal(np.asarray(post_new), np.stack(posts))


def test_exact_rule_matches_compensated_itp_engine(key):
    """eq. 18 at the system level: the counter-based exact baseline and the
    intrinsic-timing compensated po2 rule produce the same trajectory."""
    kw = dict(n_pre=20, n_post=12, eta=0.25)
    cfg_itp = EngineConfig(rule="itp", **kw)
    cfg_exact = EngineConfig(rule="exact", **kw)
    train = jax.random.bernoulli(key, 0.35, (T_STEPS, kw["n_pre"]))
    s_itp, post_itp = run_engine(init_engine(key, cfg_itp), train, cfg_itp)
    s_ex, post_ex = run_engine(init_engine(key, cfg_exact), train, cfg_exact)
    np.testing.assert_allclose(np.asarray(s_ex.w), np.asarray(s_itp.w),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(post_ex), np.asarray(post_itp))


@pytest.mark.parametrize("rule", ["linear", "imstdp"])
def test_baseline_rules_run_and_stay_bounded(key, rule):
    cfg = EngineConfig(n_pre=16, n_post=8, rule=rule, eta=0.5)
    train = jax.random.bernoulli(key, 0.4, (T_STEPS, 16))
    s, post = run_engine(init_engine(key, cfg), train, cfg)
    assert post.shape == (T_STEPS, 8)
    w = np.asarray(s.w)
    assert not np.isnan(w).any()
    assert w.min() >= cfg.w_min and w.max() <= cfg.w_max


def test_linear_rule_differs_from_exact(key):
    # small eta + short run so neither rule saturates at w_max
    kw = dict(n_pre=16, n_post=8, eta=1.0 / 64.0, quantise=False)
    train = jax.random.bernoulli(key, 0.4, (10, 16))
    ws = {}
    for rule in ("exact", "linear"):
        cfg = EngineConfig(rule=rule, **kw)
        s, _ = run_engine(init_engine(key, cfg), train, cfg)
        ws[rule] = np.asarray(s.w)
    assert np.abs(ws["exact"] - ws["linear"]).max() > 1e-4


# ---------------------------------------------------------------------------
# Network-level rule dispatch
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("net", ["2layer-snn", "5layer-csnn"])
def test_snn_exact_counter_rule_matches_itp(key, net):
    """Table II mechanism through the new API: the counter-based 'exact'
    rule and compensated 'itp' give the same run — for the fc network
    (reference einsum path) and a conv network (the counter-rule patch
    path vs the history-rule im2col oracle)."""
    B, T = 4, 12
    makers = {
        "2layer-snn": lambda r: snn.mnist_2layer(r, n_hidden=20,
                                                 quantise=False),
        "5layer-csnn": lambda r: snn.fault_csnn(r, quantise=False),
    }
    n_in = {"2layer-snn": 28 * 28, "5layer-csnn": 512 * 2}[net]
    raster = jax.random.bernoulli(key, 0.2, (T, B, n_in))
    outs = {}
    for rule in ("exact", "itp"):
        cfg = makers[net](rule)
        st = snn.init_snn(jax.random.PRNGKey(7), cfg, B)
        st2, counts = snn.run_snn(st, raster, cfg, train=True)
        outs[rule] = ([np.asarray(w) for w in st2.weights],
                      np.asarray(counts))
    for w_ex, w_itp in zip(outs["exact"][0], outs["itp"][0]):
        np.testing.assert_allclose(w_ex, w_itp, rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(outs["exact"][1], outs["itp"][1])


@pytest.mark.parametrize("rule", ["linear", "imstdp"])
def test_snn_counter_rules_learn_on_conv_net(key, rule):
    """Counter rules drive the conv nets through the reference patch path."""
    cfg = snn.fault_csnn(rule)
    B, T = 2, 8
    st = snn.init_snn(key, cfg, B)
    raster = jax.random.bernoulli(key, 0.3, (T, B, 512 * 2))
    st2, counts = snn.run_snn(st, raster, cfg, train=True)
    assert not np.isnan(np.asarray(counts)).any()
    moved = sum(float(jnp.abs(w2 - w1).max())
                for w1, w2 in zip(st.weights, st2.weights))
    assert moved > 1e-6
    for w in st2.weights:
        assert float(w.min()) >= 0.0 and float(w.max()) <= 1.0


def test_launcher_engine_mode_runs_counter_rule():
    """--engine --rule exact --backend reference end-to-end."""
    import argparse

    from repro.launch.train import run_engine_training

    args = argparse.Namespace(rule="exact", backend="reference",
                              engine_pre=16, engine_post=16, replicas=2,
                              steps=8, engine_rate=0.3)
    summary = run_engine_training(args)
    assert summary["rule"] == "exact"
    assert summary["sops_per_s"] > 0


# ---------------------------------------------------------------------------
# CounterEngine deprecation shims
# ---------------------------------------------------------------------------

def test_counter_engine_aliases_stay_green(key):
    from repro.core.baseline import (CounterEngineConfig,
                                     counter_engine_step,
                                     init_counter_engine,
                                     run_counter_engine)

    # every alias is deprecated and must say where to go instead …
    with pytest.warns(DeprecationWarning, match=r"rule='exact'"):
        cfg = CounterEngineConfig(n_pre=12, n_post=8, window=7)
    assert isinstance(cfg, EngineConfig)
    assert cfg.rule == "exact" and cfg.depth == 8
    with pytest.warns(DeprecationWarning, match="init_engine"):
        state = init_counter_engine(key, cfg)
    train = jax.random.bernoulli(key, 0.4, (25, 12))
    with pytest.warns(DeprecationWarning, match="run_engine"):
        s_alias, post_alias = run_counter_engine(state, train, cfg)
    # single-step alias too
    with pytest.warns(DeprecationWarning, match="engine_step"):
        s1, p1 = counter_engine_step(state, train[0], cfg)
    assert p1.shape == (8,)
    # … but the deprecated path must still compute the registry path
    # the shim is the unified engine: same trajectory as the direct config
    direct = EngineConfig(n_pre=12, n_post=8, depth=8, rule="exact")
    s_direct, post_direct = run_engine(init_engine(key, direct), train,
                                       direct)
    np.testing.assert_array_equal(np.asarray(s_alias.w),
                                  np.asarray(s_direct.w))
    np.testing.assert_array_equal(np.asarray(post_alias),
                                  np.asarray(post_direct))


def test_counter_engine_aliases_reject_wrong_rule(key):
    from repro.core.baseline import init_counter_engine

    with pytest.warns(DeprecationWarning), \
         pytest.raises(ValueError, match="exact"):
        init_counter_engine(key, EngineConfig(rule="itp"))
