"""Fused counter-rule datapath ≡ reference: the former ValueError cells of
the rule × backend matrix, now closed by ``repro.kernels.itp_counter``.

Parity contract (ISSUE 5 / the paper's Tables III-V comparison basis):
the fused explicit-Δt kernels must be numerically pinned against the jnp
reference at three levels — raw ops, engine scan, and network trajectory
— **bit-exact** for the arithmetic windows (``linear`` PWL, ``imstdp``
LUT) and tight-tolerance for ``exact``'s transcendental (the compiled
``exp`` may differ from XLA's on real accelerators; on the interpreter it
happens to agree bit-for-bit, which the tolerance still admits).

The property tests pin the storage format: a saturating last-spike
counter survives the round-trip through the rule's uint8 word readout and
the kernel's in-register Δt formation for every depth 1..8, including the
saturated-invalid value ``depth``.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.engine import EngineConfig, init_engine, run_engine
from repro.core.stdp import STDPParams
from repro.kernels.itp_counter.kernel import counter_delays
from repro.kernels.itp_counter.ops import (
    conv_counter_synapse_delta,
    counter_synapse_delta,
    counter_weight_update,
)
from repro.models import snn
from repro.plasticity import get_rule

COUNTER_RULES = ("exact", "linear", "imstdp")
T_STEPS = 48


def _assert_window_close(window, got, want):
    """Bit-exact for the arithmetic windows, tight-tol for 'exact'."""
    if window == "exact":
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    else:
        np.testing.assert_array_equal(got, want)


# ---------------------------------------------------------------------------
# Ops level: raw kernels vs the jnp reference
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("window", COUNTER_RULES)
@pytest.mark.parametrize("n_pre,n_post", [(32, 24), (130, 70)])
def test_counter_update_kernel_matches_reference(key, window, n_pre, n_post):
    depth = 7
    k1, k2, k3, k4, k5 = jax.random.split(key, 5)
    w = jax.random.uniform(k1, (n_pre, n_post))
    pre_s = jax.random.bernoulli(k2, 0.4, (n_pre,)).astype(jnp.float32)
    post_s = jax.random.bernoulli(k3, 0.4, (n_post,)).astype(jnp.float32)
    # counters cover the full live range AND the saturated-invalid value
    pre_t = jax.random.randint(k4, (n_pre,), 0, depth + 1).astype(jnp.uint8)
    post_t = jax.random.randint(k5, (n_post,), 0, depth + 1).astype(jnp.uint8)
    p = STDPParams()
    kw = dict(depth=depth, window=window, eta=0.25)
    ref = counter_weight_update(w, pre_s, post_s, pre_t, post_t, p, use_kernel=False, **kw)
    fused = counter_weight_update(w, pre_s, post_s, pre_t, post_t, p, interpret=True, **kw)
    _assert_window_close(window, np.asarray(fused), np.asarray(ref))


@pytest.mark.parametrize("window", COUNTER_RULES)
def test_counter_delta_kernel_matches_reference(key, window):
    depth = 7
    n_pre, n_post = 48, 40
    k1, k2, k3, k4 = jax.random.split(key, 4)
    pre_s = jax.random.bernoulli(k1, 0.4, (n_pre,)).astype(jnp.float32)
    post_s = jax.random.bernoulli(k2, 0.4, (n_post,)).astype(jnp.float32)
    pre_t = jax.random.randint(k3, (n_pre,), 0, depth + 1).astype(jnp.uint8)
    post_t = jax.random.randint(k4, (n_post,), 0, depth + 1).astype(jnp.uint8)
    p = STDPParams()
    kw = dict(depth=depth, window=window)
    ref = counter_synapse_delta(pre_s, post_s, pre_t, post_t, p, use_kernel=False, **kw)
    fused = counter_synapse_delta(pre_s, post_s, pre_t, post_t, p, interpret=True, **kw)
    _assert_window_close(window, np.asarray(fused), np.asarray(ref))


@pytest.mark.parametrize("window", COUNTER_RULES)
@pytest.mark.parametrize("m,kk,cc", [(48, 18, 12), (130, 50, 24)])
def test_conv_counter_kernel_matches_reference(key, window, m, kk, cc):
    depth = 7
    k1, k2, k3, k4 = jax.random.split(key, 4)
    pre = jax.random.bernoulli(k1, 0.3, (m, kk)).astype(jnp.float32)
    post = jax.random.bernoulli(k2, 0.3, (m, cc)).astype(jnp.float32)
    pre_t = jax.random.randint(k3, (m, kk), 0, depth + 1).astype(jnp.uint8)
    post_t = jax.random.randint(k4, (m, cc), 0, depth + 1).astype(jnp.uint8)
    p = STDPParams()
    kw = dict(depth=depth, window=window)
    ref = conv_counter_synapse_delta(pre, post, pre_t, post_t, p, use_kernel=False, **kw)
    fused = conv_counter_synapse_delta(pre, post, pre_t, post_t, p, interpret=True, **kw)
    # the matmul contraction order may differ from the einsum reference
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_counter_ops_reject_oversized_depth(key):
    with pytest.raises(ValueError, match="uint8"):
        counter_weight_update(
            jnp.zeros((4, 4)),
            jnp.zeros(4),
            jnp.zeros(4),
            jnp.zeros(4, jnp.uint8),
            jnp.zeros(4, jnp.uint8),
            STDPParams(),
            depth=300,
            window="exact",
        )


# ---------------------------------------------------------------------------
# Engine-scan level: EngineConfig(rule=..., backend="fused_interpret")
# ---------------------------------------------------------------------------


def _run_engine_pair(key, cfg_ref, t_steps=T_STEPS):
    cfg_fused = dataclasses.replace(cfg_ref, backend="fused_interpret")
    state = init_engine(key, cfg_ref)
    train = jax.random.bernoulli(key, 0.35, (t_steps, cfg_ref.n_pre))
    s_ref, post_ref = run_engine(state, train, cfg_ref)
    s_fused, post_fused = run_engine(state, train, cfg_fused)
    return s_ref, post_ref, s_fused, post_fused


@pytest.mark.parametrize("rule", COUNTER_RULES)
@pytest.mark.parametrize("n_pre,n_post", [(32, 24), (130, 70)])
def test_counter_engine_fused_matches_reference(key, rule, n_pre, n_post):
    cfg = EngineConfig(n_pre=n_pre, n_post=n_post, eta=0.25, rule=rule)
    s_ref, post_ref, s_fused, post_fused = _run_engine_pair(key, cfg)
    _assert_window_close(rule, np.asarray(s_fused.w), np.asarray(s_ref.w))
    np.testing.assert_array_equal(np.asarray(post_fused), np.asarray(post_ref))


@pytest.mark.parametrize("rule", COUNTER_RULES)
def test_counter_engine_fused_quantised(key, rule):
    cfg = EngineConfig(n_pre=48, n_post=40, eta=0.5, rule=rule, quantise=True)
    s_ref, post_ref, s_fused, post_fused = _run_engine_pair(key, cfg)
    _assert_window_close(rule, np.asarray(s_fused.w), np.asarray(s_ref.w))
    np.testing.assert_array_equal(np.asarray(post_fused), np.asarray(post_ref))


def test_fused_exact_matches_fused_itp_trajectory(key):
    """eq. 18 on the kernel path: the fused counter 'exact' kernel and the
    fused compensated ITP kernel produce the same engine trajectory — the
    paper's equivalence claim, now kernel-vs-kernel."""
    kw = dict(n_pre=20, n_post=12, eta=0.25, backend="fused_interpret")
    cfg_itp = EngineConfig(rule="itp", **kw)
    cfg_exact = EngineConfig(rule="exact", **kw)
    train = jax.random.bernoulli(key, 0.35, (T_STEPS, 20))
    s_itp, post_itp = run_engine(init_engine(key, cfg_itp), train, cfg_itp)
    s_ex, post_ex = run_engine(init_engine(key, cfg_exact), train, cfg_exact)
    np.testing.assert_allclose(np.asarray(s_ex.w), np.asarray(s_itp.w), rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(post_ex), np.asarray(post_itp))


# ---------------------------------------------------------------------------
# Network-trajectory level: fc + conv nets on the fused counter kernels
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule", COUNTER_RULES)
def test_snn_fc_counter_fused_matches_reference(key, rule):
    cfg_ref = snn.mnist_2layer(rule, n_hidden=24)
    cfg_fused = dataclasses.replace(cfg_ref, backend="fused_interpret")
    batch, t = 4, 10
    state = snn.init_snn(key, cfg_ref, batch)
    raster = jax.random.bernoulli(key, 0.2, (t, batch, 28 * 28))
    s_ref, counts_ref = snn.run_snn(state, raster, cfg_ref, train=True)
    s_fused, counts_fused = snn.run_snn(state, raster, cfg_fused, train=True)
    np.testing.assert_allclose(
        np.asarray(s_fused.weights[0]), np.asarray(s_ref.weights[0]), rtol=1e-5, atol=1e-6
    )
    np.testing.assert_array_equal(np.asarray(counts_fused), np.asarray(counts_ref))


@pytest.mark.parametrize(
    "net,rule",
    [
        ("5layer-csnn", "exact"),
        ("5layer-csnn", "linear"),
        ("6layer-dcsnn", "imstdp"),
    ],
)
def test_snn_conv_counter_fused_matches_reference(key, net, rule):
    """DCSNN/CSNN trajectories: the fused conv counter kernel tracks the
    patch-level reference over a multi-step run, spike-for-spike."""
    makers = {
        "5layer-csnn": lambda r, **kw: snn.fault_csnn(r, length=128, **kw),
        "6layer-dcsnn": lambda r, **kw: snn.fmnist_dcsnn(r, **kw),
    }
    n_in = {"5layer-csnn": 128 * 2, "6layer-dcsnn": 28 * 28}[net]
    batch, t = 2, 8
    cfg_ref = makers[net](rule)
    cfg_fused = dataclasses.replace(cfg_ref, backend="fused_interpret")
    state = snn.init_snn(key, cfg_ref, batch)
    raster = jax.random.bernoulli(key, 0.25, (t, batch, n_in))
    s_ref, counts_ref = snn.run_snn(state, raster, cfg_ref, train=True)
    s_fused, counts_fused = snn.run_snn(state, raster, cfg_fused, train=True)
    for w_f, w_r in zip(s_fused.weights, s_ref.weights):
        np.testing.assert_allclose(np.asarray(w_f), np.asarray(w_r), rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(counts_fused), np.asarray(counts_ref))


def test_launcher_engine_mode_runs_fused_counter_rule():
    """--engine --rule exact --backend fused_interpret end-to-end."""
    import argparse

    from repro.launch.train import run_engine_training

    args = argparse.Namespace(
        rule="exact",
        backend="fused_interpret",
        engine_pre=32,
        engine_post=32,
        replicas=2,
        steps=8,
        engine_rate=0.3,
    )
    summary = run_engine_training(args)
    assert summary["rule"] == "exact"
    assert summary["backend"] == "fused_interpret"
    assert summary["sops_per_s"] > 0


# ---------------------------------------------------------------------------
# Property tests: counter word ↔ in-register Δt formation round-trip
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None)
@given(data=st.data(), depth=st.integers(1, 8), n=st.integers(1, 9))
def test_counter_word_round_trips_through_delay_formation(data, depth, n):
    """For every depth 1..8: a counter value (including the saturated
    ``depth``) survives the uint8 word readout and the kernel's
    in-register Δt formation, and the validity gate opens exactly for the
    live delays 0..depth-1."""
    ts = data.draw(st.lists(st.integers(0, depth), min_size=n, max_size=n))
    state = jnp.asarray(ts, jnp.int32)
    words = get_rule("exact").readout_packed(state)
    assert words.dtype == jnp.uint8
    dt, valid = counter_delays(words, depth)
    np.testing.assert_array_equal(np.asarray(dt), np.asarray(ts))
    np.testing.assert_array_equal(
        np.asarray(valid), (np.asarray(ts) <= depth - 1).astype(np.float32)
    )


@settings(max_examples=20, deadline=None)
@given(data=st.data(), depth=st.integers(1, 8), steps=st.integers(0, 12))
def test_counter_state_saturates_and_round_trips_under_stepping(data, depth, steps):
    """Driving the rule's own step function (reset on spike, saturate at
    ``depth``) never leaves the representable word range, and the word
    readout stays the identity on the counter state."""
    rule = get_rule("exact")
    n = 4
    state = rule.init_state(n, depth)
    for _ in range(steps):
        spikes = jnp.asarray(data.draw(st.lists(st.integers(0, 1), min_size=n, max_size=n)))
        state = rule.step(state, spikes, depth=depth)
    assert int(jnp.max(state)) <= depth
    dt, valid = counter_delays(rule.readout_packed(state), depth)
    np.testing.assert_array_equal(np.asarray(dt), np.asarray(state))
