"""LLSMu approximate multiplier (paper eqs. 6-14) — error bounds + kernels."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.llsmu import (floor_log2, llsmu_fixed, llsmu_signed,
                              mitchell_fixed, mitchell_float, relative_error)


@settings(max_examples=200, deadline=None)
@given(x=st.integers(0, 1 << 16))
def test_floor_log2_exact(x):
    want = x.bit_length() - 1 if x > 0 else 0
    assert int(floor_log2(jnp.asarray(x), max_bits=18)) == max(want, 0)


def test_mitchell_error_bound():
    """Minimally-biased Mitchell: |err| ≤ c ≈ 8.34 % worst case (the +c
    compensation puts the peak error at exact powers of two), ≈ 2-3 % mean
    — matching [32]'s characterisation."""
    x = jnp.arange(1, 256)
    y = jnp.arange(1, 256)
    xx, yy = jnp.meshgrid(x, y)
    approx = mitchell_float(xx.astype(jnp.float32), yy.astype(jnp.float32))
    exact = (xx * yy).astype(jnp.float32)
    rel = jnp.abs(approx - exact) / exact
    assert float(jnp.max(rel)) < 0.0834
    assert float(jnp.mean(rel)) < 0.03


@pytest.mark.slow
def test_mitchell_fixed_matches_float_shadow():
    """Fixed-point truncation adds error only at small mantissa products."""
    x = jnp.arange(1, 200)
    y = jnp.arange(1, 200)
    xx, yy = jnp.meshgrid(x, y)
    fx = mitchell_fixed(xx, yy, frac_bits=14)
    fl = mitchell_float(xx.astype(jnp.float32), yy.astype(jnp.float32))
    rel = jnp.abs(fx.astype(jnp.float32) - fl) / jnp.maximum(fl, 1.0)
    assert float(jnp.mean(rel)) < 0.005
    assert float(jnp.max(rel)) < 0.10   # small products, truncating shifts


@pytest.mark.slow
def test_llsmu_8bit_error():
    """8×8-bit LLSMu: the Karatsuba cross term (m2−m0−m1) lets Mitchell
    errors cancel or stack — tiny products can be off by ~half their value
    (a few counts), but population-level error is small; the paper's
    quality metric (NRMSD of the resulting STDP curve) is 0.761 % [29]."""
    a = jnp.arange(256)
    b = jnp.arange(256)
    aa, bb = jnp.meshgrid(a, b)
    rel = relative_error(aa, bb, n_bits=4)
    assert float(jnp.mean(rel)) < 0.05
    exact = (aa * bb).astype(jnp.float32)
    approx = llsmu_fixed(aa, bb).astype(jnp.float32)
    nrmsd = float(jnp.sqrt(jnp.mean((approx - exact) ** 2))
                  / jnp.sqrt(jnp.mean(exact ** 2)))
    assert nrmsd < 0.04


@settings(max_examples=200, deadline=None)
@given(a=st.integers(-255, 255), b=st.integers(-255, 255))
def test_llsmu_signed_sign_correct(a, b):
    got = int(llsmu_signed(jnp.asarray(a), jnp.asarray(b)))
    want = a * b
    if want == 0:
        assert got == 0
    else:
        assert np.sign(got) == np.sign(want)
        assert abs(got - want) <= 0.7 * abs(want) + 4


def test_llsmu_zero_identity():
    assert int(llsmu_fixed(jnp.asarray(0), jnp.asarray(77))) == 0
    assert int(llsmu_fixed(jnp.asarray(77), jnp.asarray(0))) == 0


# ---------------------------------------------------------------------------
# Pallas kernel vs oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [
    100, 128,
    pytest.param(256, marks=pytest.mark.slow),
    pytest.param(384, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("nbits", [3, 4])
def test_llsmu_kernel_matches_ref(key, n, nbits):
    """Kernel vs oracle, bit-exact, odd + lane-aligned sizes, signed."""
    from repro.kernels.llsmu.ops import llsmu
    hi = 1 << (2 * nbits)
    a = jax.random.randint(key, (n,), -hi + 1, hi)
    b = jax.random.randint(jax.random.fold_in(key, 1), (n,), -hi + 1, hi)
    got = llsmu(a, b, n_bits=nbits, use_kernel=True, interpret=True)
    want = llsmu_signed(a, b, n_bits=nbits)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("shape", [(3, 40), (2, 2, 17)])
def test_llsmu_kernel_nd_shapes(key, shape):
    from repro.kernels.llsmu.ops import llsmu
    a = jax.random.randint(key, shape, 0, 255)
    b = jax.random.randint(jax.random.fold_in(key, 3), shape, 0, 255)
    got = llsmu(a, b, use_kernel=True, interpret=True)
    want = llsmu_fixed(a, b)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
