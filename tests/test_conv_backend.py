"""Patch-level fused conv backend: Pallas kernel ≡ reference patch update.

Mirrors tests/test_backend.py for the conv datapath: the im2col-fused
ITP-STDP kernel (interpret mode = exact kernel semantics) must track the
pure-jnp patch-level reference over multi-step scans for both conv2d and
conv1d layers, including quantised weights — the contract that lets the
DCSNN/CSNN stacks run identically on every ``SNNConfig.backend``.
"""
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.core.history import pack_bitplanes
from repro.core.stdp import STDPParams
from repro.kernels.itp_stdp.ops import synapse_delta
from repro.kernels.itp_stdp_conv.ops import (conv_synapse_delta,
                                             conv_synapse_delta_packed)
from repro.models import snn

DEPTH = 7


def _random_layer(key, m, kk, cc):
    ks = jax.random.split(key, 4)
    pre = jax.random.bernoulli(ks[0], 0.3, (m, kk))
    post = jax.random.bernoulli(ks[1], 0.25, (m, cc))
    pre_bits = jax.random.bernoulli(ks[2], 0.3, (DEPTH, m, kk))
    post_bits = jax.random.bernoulli(ks[3], 0.25, (DEPTH, m, cc))
    return pre, post, pre_bits, post_bits


def _pack(bits):
    """(depth, M, X) {0,1} → (M, X) uint8 words via the canonical packer."""
    return pack_bitplanes(bits)


# unaligned M / K / C on purpose: the ops padding must be exact
@pytest.mark.parametrize("m,kk,cc", [(24, 25, 12), (130, 14, 8), (300, 108, 24)])
@pytest.mark.parametrize("pairing", ["nearest", "all"])
def test_conv_kernel_matches_ref(key, m, kk, cc, pairing):
    pre, post, pre_bits, post_bits = _random_layer(key, m, kk, cc)
    params = STDPParams()
    ref = conv_synapse_delta(pre, post, pre_bits, post_bits, params,
                             pairing=pairing, use_kernel=False)
    fused = conv_synapse_delta(pre, post, pre_bits, post_bits, params,
                               pairing=pairing, use_kernel=True,
                               interpret=True)
    # atol 1e-4 on O(10) values: tiled f32 accumulation order differs
    np.testing.assert_allclose(np.asarray(fused), np.asarray(ref),
                               atol=1e-4, rtol=1e-5)


# unaligned M / K / C on purpose: the packed ops padding must be exact too
@pytest.mark.parametrize("m,kk,cc", [(24, 25, 12), (130, 14, 8)])
@pytest.mark.parametrize("pairing", ["nearest", "all"])
def test_packed_conv_kernel_bit_identical_to_unpacked(key, m, kk, cc, pairing):
    """The packed-word conv kernel is bit-identical (array_equal) to the
    bitplane conv kernel: same fused body, operands unpacked in-register."""
    pre, post, pre_bits, post_bits = _random_layer(key, m, kk, cc)
    params = STDPParams()
    unpacked = conv_synapse_delta(pre, post, pre_bits, post_bits, params,
                                  pairing=pairing, use_kernel=True,
                                  interpret=True)
    packed = conv_synapse_delta_packed(pre, post, _pack(pre_bits),
                                       _pack(post_bits), params, depth=DEPTH,
                                       pairing=pairing, use_kernel=True,
                                       interpret=True)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(unpacked))
    # and the packed reference (unpack + jnp oracle) tracks within f32 tol
    ref = conv_synapse_delta_packed(pre, post, _pack(pre_bits),
                                    _pack(post_bits), params, depth=DEPTH,
                                    pairing=pairing, use_kernel=False)
    np.testing.assert_allclose(np.asarray(packed), np.asarray(ref),
                               atol=1e-4, rtol=1e-5)


def test_single_row_matches_dense_kernel(key):
    """One patch row (P = B = 1) is exactly the dense engine Δw."""
    kk, cc = 20, 16
    pre, post, pre_bits, post_bits = _random_layer(key, 1, kk, cc)
    params = STDPParams()
    conv = conv_synapse_delta(pre, post, pre_bits, post_bits, params,
                              use_kernel=True, interpret=True)
    dense = synapse_delta(pre[0], post[0], pre_bits[:, 0], post_bits[:, 0],
                          params, interpret=True)
    np.testing.assert_allclose(np.asarray(conv), np.asarray(dense),
                               atol=1e-5, rtol=1e-5)


# --- network level ---------------------------------------------------------

def _small_conv2d(rule="itp", **kw):
    return snn.SNNConfig(
        name="small-conv2d",
        input_shape=(10, 10, 1),
        layers=(
            snn.SNNLayerSpec("conv2d", out_features=4, kernel=3),
            snn.SNNLayerSpec("pool2d", pool=2),
            snn.SNNLayerSpec("fc", out_features=12),
        ),
        neuron="izhikevich", rule=rule, gain=1.2, **kw)


def _small_conv1d(rule="itp", **kw):
    return snn.SNNConfig(
        name="small-conv1d",
        input_shape=(32, 2),
        layers=(
            snn.SNNLayerSpec("conv1d", out_features=4, kernel=5, stride=2),
            snn.SNNLayerSpec("pool1d", pool=2),
            snn.SNNLayerSpec("fc", out_features=8),
        ),
        neuron="lif", rule=rule, **kw)


def _run_net_pair(key, cfg_ref, batch=2, t_steps=8):
    cfg_fused = dataclasses.replace(cfg_ref, backend="fused_interpret")
    state = snn.init_snn(key, cfg_ref, batch)
    n_in = int(np.prod(cfg_ref.input_shape))
    raster = jax.random.bernoulli(key, 0.25, (t_steps, batch, n_in))
    s_ref, counts_ref = snn.run_snn(state, raster, cfg_ref, train=True)
    s_fused, counts_fused = snn.run_snn(state, raster, cfg_fused, train=True)
    return s_ref, counts_ref, s_fused, counts_fused


@pytest.mark.parametrize("maker", [_small_conv2d, _small_conv1d],
                         ids=["conv2d", "conv1d"])
@pytest.mark.parametrize("quantise", [False, True])
def test_conv_net_backend_equivalence(key, maker, quantise):
    """Multi-step scan: fused_interpret tracks reference on conv stacks."""
    s_ref, counts_ref, s_fused, counts_fused = _run_net_pair(
        key, maker(quantise=quantise))
    for wr, wf in zip(s_ref.weights, s_fused.weights):
        np.testing.assert_allclose(np.asarray(wf), np.asarray(wr),
                                   atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(counts_fused),
                                  np.asarray(counts_ref))


@pytest.mark.parametrize("maker", [
    snn.fmnist_dcsnn,
    lambda **kw: snn.fault_csnn(length=128, **kw),
], ids=["6layer-dcsnn", "5layer-csnn"])
def test_paper_conv_net_backend_equivalence(key, maker):
    """The paper's conv networks run end-to-end on the fused backend with
    the same weight trajectories as the reference (acceptance pin)."""
    s_ref, counts_ref, s_fused, counts_fused = _run_net_pair(
        key, maker(rule="itp"), batch=2, t_steps=5)
    assert len(s_ref.weights) == 3          # conv, conv, fc all learnable
    for wr, wf in zip(s_ref.weights, s_fused.weights):
        np.testing.assert_allclose(np.asarray(wf), np.asarray(wr),
                                   atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(counts_fused),
                                  np.asarray(counts_ref))


@pytest.mark.parametrize("maker", [
    snn.fmnist_dcsnn,
    lambda **kw: snn.fault_csnn(length=128, **kw),
], ids=["6layer-dcsnn", "5layer-csnn"])
def test_paper_conv_net_packed_bit_identical_to_unpacked(key, maker):
    """DCSNN/CSNN multi-step trajectories: the packed uint8 history datapath
    (the default fused storage format) is bit-identical to the unpacked
    bitplane kernel datapath — weights and spike counts array_equal."""
    cfg_packed = maker(rule="itp", backend="fused_interpret")
    cfg_unpacked = dataclasses.replace(cfg_packed, packed_history=False)
    assert cfg_packed.packed_history              # packed is the default
    batch, t_steps = 2, 5
    state = snn.init_snn(key, cfg_packed, batch)
    n_in = int(np.prod(cfg_packed.input_shape))
    raster = jax.random.bernoulli(key, 0.25, (t_steps, batch, n_in))
    s_p, counts_p = snn.run_snn(state, raster, cfg_packed, train=True)
    s_u, counts_u = snn.run_snn(state, raster, cfg_unpacked, train=True)
    for wp, wu in zip(s_p.weights, s_u.weights):
        np.testing.assert_array_equal(np.asarray(wp), np.asarray(wu))
    np.testing.assert_array_equal(np.asarray(counts_p), np.asarray(counts_u))


def test_conv_fused_config_constructs_clean():
    """The PR-1 'conv layers fall back' warning path is gone: a fused conv
    config builds without warnings and without raising."""
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        cfg = snn.fmnist_dcsnn("itp", backend="fused")
    assert cfg.backend == "fused"


def test_launcher_snn_mode_smoke():
    """The launch-path SNN workload runs a conv net on the kernel path."""
    import argparse

    from repro.launch.train import run_snn_training

    args = argparse.Namespace(snn="5layer-csnn", backend="fused_interpret",
                              batch=2, steps=6, engine_rate=0.3)
    summary = run_snn_training(args)
    assert summary["net"] == "5layer-csnn"
    assert summary["backend"] == "fused_interpret"
    assert summary["sops_per_s"] > 0
    assert np.isfinite(summary["mean_rate"])


def test_conv_quantised_weights_stay_on_grid(key):
    """Quantised conv training keeps every weight on the w_bits grid."""
    cfg = dataclasses.replace(_small_conv2d(), backend="fused_interpret",
                              quantise=True, w_bits=8)
    state = snn.init_snn(key, cfg, 2)
    raster = jax.random.bernoulli(key, 0.3, (6, 2, 100))
    s2, _ = snn.run_snn(state, raster, cfg, train=True)
    levels = (1 << (cfg.w_bits - 1)) - 1
    for w in s2.weights:
        scaled = np.asarray(w) * levels
        np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-4)
