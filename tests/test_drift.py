"""Mean-field synaptic drift model (§IV-A): the paper's three numbers."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.drift import (DriftParams, density, drift, drift_analytic,
                              equilibrium, iterate, paper_metrics,
                              update_curve_rmse)


def test_density_normalises():
    p = DriftParams()
    x = jnp.linspace(-80, 80, 64001)
    for w in (0.0, 0.3, 0.9):
        mass = float(jnp.trapezoid(density(x, jnp.asarray(w), p), x))
        assert abs(mass - 1.0) < 5e-3


def test_quadrature_matches_analytic():
    p = DriftParams()
    w = jnp.linspace(0.01, 0.99, 25)
    from repro.core.drift import make_rule
    g_quad = drift(w, make_rule("exact", p), p)
    g_ana = drift_analytic(w, "exact", p)
    np.testing.assert_allclose(np.asarray(g_quad), np.asarray(g_ana),
                               atol=2e-3)


def test_update_curve_rmse_reproduces_paper():
    """Paper §IV-A: 9.4753 % RMSE for uncompensated ITP."""
    rmse = update_curve_rmse(DriftParams())
    assert abs(rmse - 0.094753) < 5e-4


def test_compensated_rmse_is_zero():
    rmse = update_curve_rmse(DriftParams(), "exact", "itp")
    assert rmse < 1e-6


def test_compensated_dynamics_identical():
    """Fig. 5 left column: τ·ln2 compensation → identical trajectories."""
    p = DriftParams()
    w0 = jnp.asarray([0.2, 0.5, 0.8])
    t_exact = iterate(w0, "exact", p, n_steps=300)
    t_itp = iterate(w0, "itp", p, n_steps=300)
    np.testing.assert_allclose(np.asarray(t_exact), np.asarray(t_itp),
                               atol=1e-5)


@pytest.mark.slow
def test_paper_metrics_within_band():
    """The three §IV-A numbers: 9.4753 % / 24.69 % / 7.36 %.

    RMSE is matched tightly (it is protocol-free); the equilibrium and
    convergence errors depend on unpublished protocol details — we assert
    the same order of magnitude (DESIGN.md §7).
    """
    m = paper_metrics(n_steps=1500)
    assert abs(m["update_curve_rmse"] - 0.094753) < 5e-4
    assert m["update_curve_rmse_compensated"] < 1e-6
    assert 0.10 < m["equilibrium_rel_err"] < 0.40       # paper: 0.2469
    assert 0.02 < m["convergence_time_rel_err"] < 0.20  # paper: 0.0736


def test_equilibrium_is_stable_point():
    p = DriftParams()
    for rule in ("exact", "itp_nocomp"):
        w_star = equilibrium(rule, p)
        assert 0.0 < w_star < 1.0
        g = drift_analytic(jnp.asarray([w_star - 1e-3, w_star + 1e-3]),
                           rule, p)
        assert float(g[0]) > 0 > float(g[1])   # flow converges onto w*
