"""Bitplane spike-history ring buffer vs the naive shift-register model."""
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.history import (as_register, fixed_point_value, init_history,
                                pack_words, push, unpack_words)


def _naive_shift(raster):
    """Reference: an actual shift register per neuron (depth, steps)."""
    T, n = raster.shape
    return raster  # caller slices


@settings(max_examples=60, deadline=None)
@given(data=st.data(),
       depth=st.integers(2, 8), n=st.integers(1, 5), steps=st.integers(0, 20))
def test_ring_buffer_matches_shift_register(data, depth, n, steps):
    raster = data.draw(
        st.lists(st.lists(st.integers(0, 1), min_size=n, max_size=n),
                 min_size=steps, max_size=steps))
    h = init_history(n, depth)
    for row in raster:
        h = push(h, jnp.asarray(row, jnp.uint8))
    reg = np.asarray(as_register(h))           # (n, depth), k=0 most recent
    for i in range(n):
        for k in range(depth):
            t = steps - 1 - k                  # step that slot k refers to
            want = raster[t][i] if t >= 0 else 0
            assert reg[i, k] == want, (i, k, reg[i], raster)


@settings(max_examples=60, deadline=None)
@given(data=st.data(), depth=st.integers(1, 8), n=st.integers(1, 6))
def test_pack_unpack_roundtrip(data, depth, n):
    bits = data.draw(st.lists(
        st.lists(st.integers(0, 1), min_size=depth, max_size=depth),
        min_size=n, max_size=n))
    h = init_history(n, depth)
    # feed so that register equals bits (push oldest first)
    for k in range(depth - 1, -1, -1):
        h = push(h, jnp.asarray([bits[i][k] for i in range(n)], jnp.uint8))
    words = pack_words(h)
    reg = unpack_words(words, depth)
    np.testing.assert_array_equal(np.asarray(reg),
                                  np.asarray(bits, np.uint8))


def test_fixed_point_value_matches_place_values():
    h = init_history(8, 8)
    pattern = [1, 0, 1, 0, 0, 1, 0, 1]        # k=0 → MSB
    for k in range(7, -1, -1):
        h = push(h, jnp.asarray([pattern[k]] * 8, jnp.uint8))
    words = pack_words(h)
    v = float(fixed_point_value(words, 8)[0])
    want = sum(b * 2.0 ** -k for k, b in enumerate(pattern))
    assert abs(v - want) < 1e-6


def test_push_is_o_depth_state():
    h = init_history(4, 7)
    assert h.planes.shape == (7, 4)
    h2 = push(h, jnp.ones(4, jnp.uint8))
    # only one plane differs — the ring write touches a single slot
    diff = np.asarray(h2.planes != h.planes).any(axis=1)
    assert diff.sum() == 1
