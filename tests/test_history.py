"""Bitplane spike-history ring buffer vs the naive shift-register model."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.history import (as_register, fixed_point_value, init_history,
                                latest, pack_words, push, unpack_words)


def _naive_shift(raster):
    """Reference: an actual shift register per neuron (depth, steps)."""
    T, n = raster.shape
    return raster  # caller slices


@settings(max_examples=60, deadline=None)
@given(data=st.data(),
       depth=st.integers(2, 8), n=st.integers(1, 5), steps=st.integers(0, 20))
def test_ring_buffer_matches_shift_register(data, depth, n, steps):
    raster = data.draw(
        st.lists(st.lists(st.integers(0, 1), min_size=n, max_size=n),
                 min_size=steps, max_size=steps))
    h = init_history(n, depth)
    for row in raster:
        h = push(h, jnp.asarray(row, jnp.uint8))
    reg = np.asarray(as_register(h))           # (n, depth), k=0 most recent
    for i in range(n):
        for k in range(depth):
            t = steps - 1 - k                  # step that slot k refers to
            want = raster[t][i] if t >= 0 else 0
            assert reg[i, k] == want, (i, k, reg[i], raster)


@settings(max_examples=60, deadline=None)
@given(data=st.data(), depth=st.integers(1, 8), n=st.integers(1, 6))
def test_pack_unpack_roundtrip(data, depth, n):
    bits = data.draw(st.lists(
        st.lists(st.integers(0, 1), min_size=depth, max_size=depth),
        min_size=n, max_size=n))
    h = init_history(n, depth)
    # feed so that register equals bits (push oldest first)
    for k in range(depth - 1, -1, -1):
        h = push(h, jnp.asarray([bits[i][k] for i in range(n)], jnp.uint8))
    words = pack_words(h)
    reg = unpack_words(words, depth)
    np.testing.assert_array_equal(np.asarray(reg),
                                  np.asarray(bits, np.uint8))


@settings(max_examples=60, deadline=None)
@given(data=st.data(),
       depth=st.integers(1, 8), n=st.integers(1, 6), steps=st.integers(0, 20))
def test_pack_unpack_roundtrip_any_head(data, depth, n, steps):
    """pack→unpack is the identity for every depth ∈ 1..8 and every
    ring-buffer head position (``steps`` pushes leave head = (steps-1) %
    depth), not just the aligned head the depth-length feed produces."""
    raster = data.draw(
        st.lists(st.lists(st.integers(0, 1), min_size=n, max_size=n),
                 min_size=steps, max_size=steps))
    h = init_history(n, depth)
    for row in raster:
        h = push(h, jnp.asarray(row, jnp.uint8))
    reg = np.asarray(as_register(h))               # (n, depth), the oracle
    words = pack_words(h)
    np.testing.assert_array_equal(np.asarray(unpack_words(words, depth)), reg)
    # MSB placement is depth-independent: bit 7-k of the word is register k
    w = np.asarray(words)
    for k in range(depth):
        np.testing.assert_array_equal((w >> (7 - k)) & 1, reg[:, k])
    # the spare low bits of a depth<8 word are always zero
    if depth < 8:
        assert (w & ((1 << (8 - depth)) - 1) == 0).all()
    # latest() is the k=0 column read without the register relayout
    np.testing.assert_array_equal(np.asarray(latest(h)), reg[:, 0])


@pytest.mark.parametrize("depth", [7, 8])
def test_fixed_point_value_is_the_po2_place_value_oracle(key, depth):
    """The /128 scale reads Σ h[k]·2^(-k) for depth 7 AND 8: the word value
    equals the raw (uncompensated, τ'=1) all-to-all po2 register read the
    packed kernels are pinned against (the eq. 2 accumulation)."""
    import jax
    from repro.core.stdp import magnitudes_depth_major, po2_weights
    n = 32
    h = init_history(n, depth)
    for t in range(depth + 3):                     # wrap the ring buffer
        h = push(h, jax.random.bernoulli(jax.random.fold_in(key, t), 0.4,
                                         (n,)).astype(jnp.uint8))
    words = pack_words(h)
    got = np.asarray(fixed_point_value(words, depth))
    # oracle 1: explicit place values off the register view
    reg = np.asarray(as_register(h), np.float32)
    want = (reg * (2.0 ** -np.arange(depth))).sum(axis=1)
    np.testing.assert_allclose(got, want, atol=1e-6)
    # oracle 2: the rule readout with the raw po2 vector (A=1, τ=1, no
    # compensation ⇒ po2_weights = 2^-k exactly)
    bits = np.asarray(as_register(h)).T            # (depth, n)
    mags = magnitudes_depth_major(jnp.asarray(bits), 1.0, 1.0,
                                  pairing="all", compensate=False)
    np.testing.assert_allclose(got, np.asarray(mags), atol=1e-6)
    assert float(po2_weights(depth, 1.0, compensate=False)[1]) == 0.5


def test_fixed_point_value_matches_place_values():
    h = init_history(8, 8)
    pattern = [1, 0, 1, 0, 0, 1, 0, 1]        # k=0 → MSB
    for k in range(7, -1, -1):
        h = push(h, jnp.asarray([pattern[k]] * 8, jnp.uint8))
    words = pack_words(h)
    v = float(fixed_point_value(words, 8)[0])
    want = sum(b * 2.0 ** -k for k, b in enumerate(pattern))
    assert abs(v - want) < 1e-6


def test_push_is_o_depth_state():
    h = init_history(4, 7)
    assert h.planes.shape == (7, 4)
    h2 = push(h, jnp.ones(4, jnp.uint8))
    # only one plane differs — the ring write touches a single slot
    diff = np.asarray(h2.planes != h.planes).any(axis=1)
    assert diff.sum() == 1
