"""Online-plasticity serving: session isolation, LRU store, persistence,
eval-traffic read-only-ness, and deterministic async drain."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import plasticity
from repro.core.engine import EngineConfig, EngineState, engine_step
from repro.core.lif import LIFState
from repro.serve import (Request, ServeConfig, Server, SessionState,
                         SessionStore, serve_step)

RULES = ("itp", "exact", "mstdp")


def _cfg(rule="itp", **kw):
    kw.setdefault("n_pre", 8)
    kw.setdefault("n_post", 4)
    return EngineConfig(rule=rule, **kw)


def _raster(key, t, n, rate=0.4):
    return (jax.random.uniform(key, (t, n)) < rate).astype(np.float32)


def _assert_state_equal(a: SessionState, b: SessionState):
    for x, y in zip(jax.tree_util.tree_leaves(a),
                    jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


# ---------------------------------------------------------------------------
# session isolation: interleaved == solo, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", RULES)
def test_interleaved_matches_solo_bitwise(key, rule):
    """A session's trajectory must not depend on its batchmates: the same
    request sequence, served solo vs interleaved with other sessions,
    yields bit-identical spikes, weights, and word planes."""
    cfg = _cfg(rule)
    scfg = ServeConfig(max_batch=4, t_steps=6, theta_plus=0.05)
    ras = [_raster(jax.random.fold_in(key, i), 6, cfg.n_pre)
           for i in range(6)]

    inter = Server(cfg, scfg)
    t0 = inter.submit(Request("alice", ras[0]))
    inter.submit(Request("bob", ras[1]))
    inter.submit(Request("carol", ras[2]))
    inter.step()
    t1 = inter.submit(Request("alice", ras[3]))
    inter.submit(Request("bob", ras[4]))
    inter.step()

    solo = Server(cfg, scfg)
    s0 = solo.submit(Request("alice", ras[0]))
    solo.step()
    s1 = solo.submit(Request("alice", ras[3]))
    solo.step()

    np.testing.assert_array_equal(inter.poll(t0).post, solo.poll(s0).post)
    np.testing.assert_array_equal(inter.poll(t1).post, solo.poll(s1).post)
    _assert_state_equal(inter.store.peek("alice"), solo.store.peek("alice"))


@pytest.mark.parametrize("rule", RULES)
def test_sliced_serving_matches_unbroken_rollout(key, rule):
    """Two served slices == one uninterrupted engine rollout: the
    word-serialize → rehydrate round trip across serve_step boundaries
    loses nothing."""
    cfg = _cfg(rule)
    t = 5
    scfg = ServeConfig(max_batch=2, t_steps=t)
    x = _raster(key, 2 * t, cfg.n_pre)

    store = SessionStore(cfg)
    serve_step(store, [Request("u", x[:t])], scfg)
    serve_step(store, [Request("u", x[t:])], scfg)
    served = store.peek("u")

    plan = plasticity.make_plan(cfg)
    fresh = store.fresh_state("u")
    state = EngineState(fresh.w, plan.session_state(fresh.pre_words),
                        plan.session_state(fresh.post_words),
                        LIFState(fresh.v))
    for i in range(2 * t):
        state, _ = engine_step(state, jnp.asarray(x[i]), cfg)

    np.testing.assert_array_equal(np.asarray(served.w), np.asarray(state.w))
    for got, want in zip(served.pre_words,
                         plan.session_words(state.pre_hist)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    for got, want in zip(served.post_words,
                         plan.session_words(state.post_hist)):
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert int(served.t) == 2 * t


# ---------------------------------------------------------------------------
# the store: LRU, capacity, byte accounting
# ---------------------------------------------------------------------------

def test_lru_eviction_and_capacity():
    store = SessionStore(_cfg(), capacity=2)
    store.init("a")
    store.init("b")
    store.get("a")                       # refresh: b is now LRU
    store.init("c")                      # evicts b
    assert store.session_ids == ("a", "c")
    assert "b" not in store and len(store) == 2
    store.touch("a")
    assert store.evict() == "c"


def test_invalid_session_ids_rejected():
    store = SessionStore(_cfg())
    for bad in ("", "a/b", "a\\b", "a\x00b"):
        with pytest.raises(ValueError):
            store.init(bad)


def test_session_init_deterministic_in_seed_and_sid():
    a = SessionStore(_cfg(), seed=3).fresh_state("alice")
    b = SessionStore(_cfg(), seed=3).fresh_state("alice")
    _assert_state_equal(a, b)
    c = SessionStore(_cfg(), seed=3).fresh_state("bob")
    assert not np.array_equal(np.asarray(a.w), np.asarray(c.w))


@pytest.mark.parametrize("rule", RULES)
def test_plasticity_cache_at_most_two_bytes_per_neuron(rule):
    """The paper's storage claim at the serving layer: resident learning
    state is <= 2 bytes/neuron (history word, + eligibility for mstdp)."""
    store = SessionStore(_cfg(rule))
    n = store.cfg.n_pre + store.cfg.n_post
    assert store.state_bytes_per_session() <= 2 * n
    assert store.resident_bytes_per_session() > store.state_bytes_per_session()
    assert store.sessions_per_gb() == (1 << 30) / store.state_bytes_per_session()


# ---------------------------------------------------------------------------
# persistence
# ---------------------------------------------------------------------------

def test_checkpoint_restore_roundtrip(key, tmp_path):
    cfg = _cfg("mstdp")
    scfg = ServeConfig(max_batch=2, t_steps=4)
    sv = Server(cfg, scfg)
    for i in range(4):
        sv.submit(Request(f"u{i % 3}", _raster(jax.random.fold_in(key, i),
                                               4, cfg.n_pre)))
    sv.drain()
    sv.checkpoint(str(tmp_path))

    sv2 = Server(cfg, scfg)
    sv2.restore(str(tmp_path))
    assert sv2.store.session_ids == sv.store.session_ids   # LRU order too
    for sid in sv.store:
        _assert_state_equal(sv.store.peek(sid), sv2.store.peek(sid))

    # restored sessions continue bit-identically
    x = _raster(jax.random.fold_in(key, 99), 4, cfg.n_pre)
    ta, tb = sv.submit(Request("u0", x)), sv2.submit(Request("u0", x))
    sv.step(), sv2.step()
    np.testing.assert_array_equal(sv.poll(ta).post, sv2.poll(tb).post)


def test_restore_rejects_mismatched_config(key, tmp_path):
    sv = Server(_cfg("itp"), ServeConfig(max_batch=1, t_steps=2))
    sv.submit(Request("u", _raster(key, 2, 8)))
    sv.drain()
    sv.checkpoint(str(tmp_path))
    other = Server(_cfg("exact"), ServeConfig(max_batch=1, t_steps=2))
    with pytest.raises(ValueError, match="rule"):
        other.restore(str(tmp_path))


# ---------------------------------------------------------------------------
# eval traffic is read-only
# ---------------------------------------------------------------------------

def test_learn_false_freezes_session(key):
    cfg = _cfg("mstdp")
    scfg = ServeConfig(max_batch=2, t_steps=4, theta_plus=0.1)
    store = SessionStore(cfg)
    serve_step(store, [Request("u", _raster(key, 4, cfg.n_pre))], scfg)
    before = store.peek("u")

    x = _raster(jax.random.fold_in(key, 1), 4, cfg.n_pre)
    (res,) = serve_step(store, [Request("u", x, learn=False)], scfg)
    assert not res.learned
    _assert_state_equal(before, store.peek("u"))

    # ... and the eval pass observed the learned state: the same raster
    # served with learn=True spikes identically on its first slice
    (res2,) = serve_step(store, [Request("u", x, learn=True)], scfg)
    assert res2.learned
    np.testing.assert_array_equal(res.post, res2.post)
    assert int(store.peek("u").t) == 8


# ---------------------------------------------------------------------------
# batching + async server semantics
# ---------------------------------------------------------------------------

def test_serve_step_validates_batches(key):
    cfg = _cfg()
    scfg = ServeConfig(max_batch=2, t_steps=4)
    store = SessionStore(cfg)
    x = _raster(key, 4, cfg.n_pre)
    with pytest.raises(ValueError, match="max_batch"):
        serve_step(store, [Request(f"u{i}", x) for i in range(3)], scfg)
    with pytest.raises(ValueError, match="duplicate"):
        serve_step(store, [Request("u", x), Request("u", x)], scfg)
    with pytest.raises(ValueError, match="learn"):
        serve_step(store, [Request("a", x), Request("b", x, learn=False)],
                   scfg)
    with pytest.raises(ValueError, match="shape"):
        serve_step(store, [Request("a", x[:2])], scfg)
    assert serve_step(store, [], scfg) == []


def test_admission_is_deterministic_fifo(key):
    """Batches split at learn-flag changes and repeated sids, in queue
    order — the rule the solo-vs-interleaved bit-identity relies on."""
    cfg = _cfg()
    scfg = ServeConfig(max_batch=8, t_steps=2)
    sv = Server(cfg, scfg)
    x = _raster(key, 2, cfg.n_pre)
    sv.submit(Request("a", x))
    sv.submit(Request("b", x))
    sv.submit(Request("a", x))           # repeat sid → next batch
    sv.submit(Request("c", x, learn=False))
    assert sv.step() == 2                # [a, b]
    assert sv.step() == 1                # [a] again
    assert sv.step() == 1                # [c] (learn flag flip)
    assert sv.step() == 0 and sv.pending == 0


def test_async_drain_matches_synchronous_steps(key):
    """Background-thread serving + shutdown(drain=True) is bit-identical
    to driving step() by hand: every request answered, same results."""
    cfg = _cfg("mstdp")
    scfg = ServeConfig(max_batch=3, t_steps=4)
    reqs = [Request(f"s{i % 4}", _raster(jax.random.fold_in(key, i),
                                         4, cfg.n_pre), learn=(i % 5 != 4))
            for i in range(12)]

    sva = Server(cfg, scfg)
    ta = [sva.submit(Request(r.sid, r.raster, r.learn)) for r in reqs]
    sva.start()
    sva.shutdown(drain=True)

    svb = Server(cfg, scfg)
    tb = [svb.submit(Request(r.sid, r.raster, r.learn)) for r in reqs]
    svb.drain()

    assert sva.pending == 0 and svb.pending == 0
    for x, y in zip(ta, tb):
        ra, rb = sva.poll(x), svb.poll(y)
        assert ra is not None and rb is not None
        np.testing.assert_array_equal(ra.post, rb.post)
    for sid in svb.store:
        _assert_state_equal(sva.store.peek(sid), svb.store.peek(sid))
