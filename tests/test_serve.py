"""Serving layer: prefill/decode consistency, int8 KV, the batching server."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import transformer
from repro.serve import (Request, ServeConfig, Server, init_cache,
                         make_serve_step, prefill, sample)


def test_serve_step_shapes(key):
    cfg = get_smoke_config("qwen2-1.5b")
    params = transformer.init_model(key, cfg)
    scfg = ServeConfig(max_tokens=32, batch=3)
    step = jax.jit(make_serve_step(cfg, scfg))
    cache = init_cache(cfg, scfg)
    logits, cache2 = step(params, cache, jnp.zeros((3, 1), jnp.int32),
                          jnp.asarray(0))
    assert logits.shape == (3, 1, cfg.vocab_size)
    assert cache2.kv.k.shape == cache.kv.k.shape


@pytest.mark.slow
def test_prefill_matches_stepwise(key):
    cfg = get_smoke_config("qwen3-0.6b")
    params = transformer.init_model(key, cfg)
    scfg = ServeConfig(max_tokens=16, batch=2)
    step = make_serve_step(cfg, scfg)
    toks = jax.random.randint(key, (2, 8), 0, cfg.vocab_size)
    logits_p, cache_p = prefill(params, cfg, init_cache(cfg, scfg), toks,
                                step)
    cache_s = init_cache(cfg, scfg)
    for t in range(8):
        logits_s, cache_s = step(params, cache_s, toks[:, t:t + 1],
                                 jnp.asarray(t))
    np.testing.assert_allclose(np.asarray(logits_p, np.float32),
                               np.asarray(logits_s, np.float32), atol=1e-2)
    np.testing.assert_allclose(
        np.asarray(cache_p.kv.k, np.float32),
        np.asarray(cache_s.kv.k, np.float32), atol=1e-2)


def test_sample_greedy_vs_temperature(key):
    logits = jnp.asarray([[[0.1, 3.0, 0.2]]])
    assert int(sample(key, logits, 0.0)[0]) == 1
    # temperature draws vary but stay in range
    draws = {int(sample(jax.random.fold_in(key, i), logits, 2.0)[0])
             for i in range(20)}
    assert draws <= {0, 1, 2} and len(draws) > 1


def test_server_completes_requests(key):
    cfg = get_smoke_config("qwen2-1.5b")
    params = transformer.init_model(key, cfg)
    scfg = ServeConfig(max_tokens=64, batch=2)
    server = Server(params, cfg, scfg)
    for i in range(4):
        server.submit(Request(uid=i, prompt=[1, 2, 3], max_new=5))
    done = server.run(max_steps=200)
    assert len(done) == 4
    assert all(len(r.out) == 5 for r in done)
    assert all(0 <= t < cfg.vocab_size for r in done for t in r.out)


def test_server_int8_kv(key):
    cfg = get_smoke_config("yi-9b")
    params = transformer.init_model(key, cfg)
    scfg = ServeConfig(max_tokens=32, batch=2, kv_dtype="int8")
    server = Server(params, cfg, scfg)
    server.submit(Request(uid=0, prompt=[5, 6], max_new=4))
    done = server.run(max_steps=64)
    assert len(done) == 1 and len(done[0].out) == 4
