"""ITP-STDP learning engine (§III-B/V): dynamics, quantisation, kernel parity."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import history as H
from repro.core.engine import (EngineConfig, init_engine,
                               prototype_engine, run_engine)


def test_prototype_is_4x4(key):
    cfg, st = prototype_engine(key)
    assert st.w.shape == (4, 4)
    assert st.pre_hist.planes.shape == (7, 4)


def test_engine_run_bounds_and_shapes(key):
    cfg = EngineConfig(n_pre=16, n_post=8, eta=0.5)
    st = init_engine(key, cfg)
    train = jax.random.bernoulli(key, 0.3, (50, 16))
    st2, post = run_engine(st, train, cfg)
    assert post.shape == (50, 8)
    assert float(st2.w.min()) >= cfg.w_min
    assert float(st2.w.max()) <= cfg.w_max
    assert not np.isnan(np.asarray(st2.w)).any()


def test_engine_weights_move(key):
    cfg = EngineConfig(n_pre=8, n_post=8, eta=0.25)
    st = init_engine(key, cfg)
    train = jax.random.bernoulli(key, 0.4, (100, 8))
    st2, _ = run_engine(st, train, cfg)
    assert float(jnp.abs(st2.w - st.w).max()) > 1e-3


def test_engine_quantised_weights_on_grid(key):
    cfg = EngineConfig(n_pre=8, n_post=8, quantise=True, w_bits=8, eta=0.5)
    st = init_engine(key, cfg)
    train = jax.random.bernoulli(key, 0.4, (30, 8))
    st2, _ = run_engine(st, train, cfg)
    levels = (1 << (cfg.w_bits - 1)) - 1
    scaled = np.asarray(st2.w) * levels
    np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-4)


def test_engine_compensated_itp_equals_exact_semantics(key):
    """Comp. ITP reads e^(-k/τ) exactly — same engine trajectory as an
    engine evaluating the base-e kernel (the paper's equivalence at the
    system level)."""
    cfg_itp = EngineConfig(n_pre=8, n_post=8, compensate=True)
    st = init_engine(key, cfg_itp)
    train = jax.random.bernoulli(key, 0.35, (60, 8))
    st_a, post_a = run_engine(st, train, cfg_itp)
    # manually run with explicit exp(-k/τ) readout
    from repro.core.stdp import synapse_update
    from repro.core.lif import lif_step

    w = st.w
    pre_h, post_h = st.pre_hist, st.post_hist
    neurons = st.neurons
    for t in range(train.shape[0]):
        pre = train[t]
        i_in = pre.astype(jnp.float32) @ w
        neurons, post = lif_step(neurons, i_in, cfg_itp.lif)
        w = synapse_update(w, pre, post, H.as_register(pre_h),
                           H.as_register(post_h), cfg_itp.stdp,
                           pairing="nearest", compensate=True,
                           eta=cfg_itp.eta)
        pre_h = H.push(pre_h, pre)
        post_h = H.push(post_h, post)
    np.testing.assert_allclose(np.asarray(st_a.w), np.asarray(w), rtol=1e-6)


def test_engine_kernel_backed_step_matches_reference(key):
    """One engine step with the Pallas weight update ≡ the core path."""
    from repro.kernels.itp_stdp.ops import engine_weight_update
    cfg = EngineConfig(n_pre=32, n_post=24, eta=0.5)
    st = init_engine(key, cfg)
    # roll some history in
    train = jax.random.bernoulli(key, 0.4, (10, 32))
    st, _ = run_engine(st, train, cfg)
    pre = jax.random.bernoulli(jax.random.fold_in(key, 9), 0.5, (32,))
    i_in = pre.astype(jnp.float32) @ st.w
    from repro.core.lif import lif_step
    _, post = lif_step(st.neurons, i_in, cfg.lif)
    w_kernel = engine_weight_update(st.w, pre, post, st.pre_hist,
                                    st.post_hist, cfg.stdp,
                                    pairing=cfg.pairing, eta=cfg.eta,
                                    use_kernel=True, interpret=True)
    from repro.core.stdp import synapse_update
    w_ref = synapse_update(st.w, pre, post, H.as_register(st.pre_hist),
                           H.as_register(st.post_hist), cfg.stdp,
                           pairing=cfg.pairing, eta=cfg.eta)
    np.testing.assert_allclose(np.asarray(w_kernel), np.asarray(w_ref),
                               rtol=1e-5, atol=1e-6)


def test_engine_silent_input_is_stable(key):
    cfg = EngineConfig(n_pre=8, n_post=8)
    st = init_engine(key, cfg)
    train = jnp.zeros((20, 8), jnp.bool_)
    st2, post = run_engine(st, train, cfg)
    np.testing.assert_allclose(np.asarray(st2.w), np.asarray(st.w))
    assert not bool(post.any())
