"""Paper networks (§IV-C): structure, learning, and the Table II parity
protocol at smoke scale."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import encode_batch, synthetic_digits, synthetic_fault
from repro.models import snn


@pytest.mark.slow
@pytest.mark.parametrize("maker,sampler", [
    (snn.mnist_2layer, lambda k, n: synthetic_digits(k, n)),
    (snn.fmnist_dcsnn, lambda k, n: synthetic_digits(k, n)),
    (snn.fault_csnn, lambda k, n: synthetic_fault(k, n, length=512)),
])
def test_network_step_shapes(key, maker, sampler):
    cfg = maker("itp")
    B, T = 2, 8
    st = snn.init_snn(key, cfg, B)
    x, y = sampler(key, B)
    raster = encode_batch(key, x, T)
    st2, counts = snn.run_snn(st, raster, cfg, train=True)
    assert counts.shape == (B, snn.feature_size(cfg))
    assert not np.isnan(np.asarray(counts)).any()
    for w in st2.weights:
        assert float(w.min()) >= 0.0 and float(w.max()) <= 1.0


def test_weights_learn(key):
    cfg = snn.mnist_2layer("itp", quantise=False)
    B, T = 8, 20
    st = snn.init_snn(key, cfg, B)
    x, _ = synthetic_digits(key, B)
    raster = encode_batch(key, x, T)
    st2, _ = snn.run_snn(st, raster, cfg, train=True)
    assert float(jnp.abs(st2.weights[0] - st.weights[0]).max()) > 1e-4


def test_train_false_freezes_weights(key):
    cfg = snn.fault_csnn("itp")
    B, T = 2, 10
    st = snn.init_snn(key, cfg, B)
    x, _ = synthetic_fault(key, B, length=512)
    raster = encode_batch(key, x, T)
    st2, _ = snn.run_snn(st, raster, cfg, train=False)
    for w1, w2 in zip(st.weights, st2.weights):
        np.testing.assert_array_equal(np.asarray(w1), np.asarray(w2))


def test_exact_and_compensated_itp_identical_trajectories(key):
    """Table II mechanism: 'exact' and compensated ITP read the same
    e^(-k/τ) values on the integer grid — identical runs, not just
    statistically similar."""
    B, T = 4, 15
    x, _ = synthetic_digits(key, B)
    raster = encode_batch(key, x, T)
    outs = {}
    for rule in ("exact", "itp"):
        cfg = snn.mnist_2layer(rule, quantise=False)
        st = snn.init_snn(jax.random.PRNGKey(7), cfg, B)
        st2, counts = snn.run_snn(st, raster, cfg, train=True)
        outs[rule] = (np.asarray(st2.weights[0]), np.asarray(counts))
    np.testing.assert_allclose(outs["exact"][0], outs["itp"][0], rtol=1e-6)
    np.testing.assert_array_equal(outs["exact"][1], outs["itp"][1])


def test_uncompensated_differs_but_close(key):
    B, T = 4, 15
    x, _ = synthetic_digits(key, B)
    raster = encode_batch(key, x, T)
    w = {}
    for rule in ("itp", "itp_nocomp"):
        cfg = snn.mnist_2layer(rule, quantise=False)
        st = snn.init_snn(jax.random.PRNGKey(7), cfg, B)
        st2, _ = snn.run_snn(st, raster, cfg, train=True)
        w[rule] = np.asarray(st2.weights[0])
    diff = np.abs(w["itp"] - w["itp_nocomp"])
    assert diff.max() > 1e-6          # the rules do differ...
    assert diff.max() < 0.2           # ...by a bounded amount (§IV-A)


def test_quantised_weights_on_grid(key):
    cfg = snn.mnist_2layer("itp", quantise=True, w_bits=8)
    B, T = 4, 10
    st = snn.init_snn(key, cfg, B)
    x, _ = synthetic_digits(key, B)
    st2, _ = snn.run_snn(st, encode_batch(key, x, T), cfg, train=True)
    levels = (1 << (cfg.w_bits - 1)) - 1
    scaled = np.asarray(st2.weights[0]) * levels
    np.testing.assert_allclose(scaled, np.round(scaled), atol=1e-4)


@pytest.mark.slow
def test_learning_beats_chance(key):
    """End-to-end protocol at tiny scale: STDP features + ridge readout
    beat chance on the synthetic digits."""
    cfg = snn.mnist_2layer("itp")
    B, T, rounds = 16, 25, 4
    st = snn.init_snn(key, cfg, B)
    k = key
    for _ in range(rounds):
        k, kd, ke = jax.random.split(k, 3)
        x, _ = synthetic_digits(kd, B)
        st, _ = snn.run_snn(st, encode_batch(ke, x, T), cfg, train=True)
        st = snn.reset_dynamics(st, cfg, B)

    def feats(n, seed):
        fs, ls = [], []
        kk = jax.random.PRNGKey(seed)
        s = st
        for _ in range(n // B):
            kk, kd, ke = jax.random.split(kk, 3)
            x, y = synthetic_digits(kd, B)
            s = snn.reset_dynamics(s, cfg, B)
            s, c = snn.run_snn(s, encode_batch(ke, x, T), cfg, train=False)
            fs.append(c)
            ls.append(y)
        return jnp.concatenate(fs), jnp.concatenate(ls)

    Xtr, ytr = feats(64, 10)
    Xte, yte = feats(48, 20)
    W = snn.fit_readout(Xtr, ytr, 10)
    acc = snn.readout_accuracy(W, Xte, yte)
    assert acc > 0.15   # chance = 0.10


def test_readout_ridge_sanity(key):
    X = jax.random.normal(key, (200, 16))
    w_true = jax.random.normal(jax.random.fold_in(key, 1), (16, 4))
    y = jnp.argmax(X @ w_true, axis=-1)
    W = snn.fit_readout(X, y, 4, l2=1e-4)
    assert snn.readout_accuracy(W, X, y) > 0.9
