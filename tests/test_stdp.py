"""Core STDP rule family: the paper's central equivalence claims."""
import math

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.stdp import (RULES, STDPParams, a2a_delta_from_history,
                             exact_stdp, imstdp, itp_stdp, linear_stdp,
                             nn_delta_from_history, pair_gate, po2_weights,
                             synapse_update)

LN2 = math.log(2.0)


# ---------------------------------------------------------------------------
# Paper eq. 18/20: compensated ITP ≡ exact STDP
# ---------------------------------------------------------------------------

@settings(max_examples=200, deadline=None)
@given(dt=st.floats(-50.0, 50.0, allow_nan=False),
       a_plus=st.floats(0.1, 4.0), a_minus=st.floats(0.1, 4.0),
       tau=st.floats(0.5, 20.0))
def test_itp_compensated_equals_exact(dt, a_plus, a_minus, tau):
    p = STDPParams(a_plus=a_plus, a_minus=a_minus, tau_plus=tau, tau_minus=tau)
    exact = float(exact_stdp(jnp.asarray(dt), p))
    itp = float(itp_stdp(jnp.asarray(dt), p, compensate=True))
    assert abs(exact - itp) <= 1e-5 * max(1.0, abs(exact))


def test_itp_uncompensated_is_base2():
    p = STDPParams()
    dt = jnp.linspace(-10, 10, 201)
    got = itp_stdp(dt, p, compensate=False)
    want = jnp.where(dt >= 0, p.a_plus * 2.0 ** (-dt / p.tau_plus),
                     -p.a_minus * 2.0 ** (dt / p.tau_minus))
    np.testing.assert_allclose(got, want, rtol=1e-6)


def test_uncompensated_error_is_bounded():
    """§IV-A: the nocomp deviation is a τ change, bounded on the window."""
    p = STDPParams()
    dt = jnp.linspace(0.0, 20.0, 400)
    e = exact_stdp(dt, p)
    i = itp_stdp(dt, p, compensate=False)
    rel = jnp.max(jnp.abs(e - i))
    assert float(rel) < 0.25 * p.a_plus   # bounded, nonzero
    assert float(rel) > 0.01 * p.a_plus


def test_rule_registry():
    p = STDPParams()
    for name, rule in RULES.items():
        out = rule(jnp.asarray([-2.0, 0.0, 2.0]), p)
        assert out.shape == (3,)
        assert float(out[1]) > 0  # dt=0 → LTP side
        assert float(out[0]) < 0 <= float(out[2])


def test_linear_and_imstdp_approximate_exact():
    p = STDPParams()
    dt = jnp.linspace(-8, 8, 321)
    e = exact_stdp(dt, p)
    for rule in (linear_stdp, imstdp):
        a = rule(dt, p)
        # same sign structure, bounded deviation (these are the baselines
        # whose error the paper criticises — nonzero but sane)
        assert float(jnp.max(jnp.abs(a - e))) < 1.2
        assert float(jnp.mean(jnp.abs(a - e))) > 1e-4


# ---------------------------------------------------------------------------
# Intrinsic-timing readout (Figs. 2-3, 10-11)
# ---------------------------------------------------------------------------

def test_po2_weights_compensated_matches_exact_kernel():
    w = po2_weights(8, 4.0, compensate=True)
    k = jnp.arange(8, dtype=jnp.float32)
    np.testing.assert_allclose(w, jnp.exp(-k / 4.0), rtol=1e-6)


def test_po2_weights_uncompensated_is_place_value():
    w = po2_weights(8, 1.0, compensate=False)
    np.testing.assert_allclose(w, 2.0 ** -jnp.arange(8, dtype=jnp.float32),
                               rtol=1e-6)


@settings(max_examples=100, deadline=None)
@given(bits=st.lists(st.integers(0, 1), min_size=7, max_size=7))
def test_nn_readout_is_priority_encode(bits):
    """NN pairing reads exactly the most recent spike (the MSB mask)."""
    h = jnp.asarray([bits], jnp.float32)          # (1, depth)
    got = float(nn_delta_from_history(h, 1.0, 4.0, compensate=False)[0])
    if 1 in bits:
        k = bits.index(1)
        assert abs(got - 2.0 ** (-k / 4.0)) < 1e-6
    else:
        assert got == 0.0


@settings(max_examples=100, deadline=None)
@given(bits=st.lists(st.integers(0, 1), min_size=7, max_size=7))
def test_a2a_readout_is_fixed_point_value(bits):
    """A2A pairing = the binary-fraction read of the whole register."""
    h = jnp.asarray([bits], jnp.float32)
    got = float(a2a_delta_from_history(h, 1.0, 1.0, compensate=False)[0])
    want = sum(b * 2.0 ** (-k) for k, b in enumerate(bits))
    assert abs(got - want) < 1e-6


def test_a2a_equals_sum_over_pairs():
    """Eq. 2: the fixed-point read IS the all-to-all accumulation."""
    p = STDPParams()
    h = jnp.asarray([[1, 0, 1, 1, 0, 0, 1]], jnp.float32)
    got = float(a2a_delta_from_history(h, p.a_plus, p.tau_plus,
                                       compensate=True)[0])
    want = sum(p.a_plus * math.exp(-k / p.tau_plus)
               for k, b in enumerate([1, 0, 1, 1, 0, 0, 1]) if b)
    assert abs(got - want) < 1e-5


# ---------------------------------------------------------------------------
# Control logic (§V-A) and the full synapse update
# ---------------------------------------------------------------------------

def test_pair_gate_xor_logic():
    pre = jnp.asarray([0, 0, 1, 1], jnp.bool_)
    post = jnp.asarray([0, 1, 0, 1], jnp.bool_)
    ltp, ltd = pair_gate(pre, post)
    np.testing.assert_array_equal(np.asarray(ltp), [False, True, False, False])
    np.testing.assert_array_equal(np.asarray(ltd), [False, False, True, False])


def test_synapse_update_clips_and_signs(key):
    n_pre, n_post, depth = 8, 6, 7
    p = STDPParams()
    w = jnp.full((n_pre, n_post), 0.5)
    pre_h = jax.random.bernoulli(key, 0.4, (n_pre, depth)).astype(jnp.float32)
    post_h = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.4,
                                  (n_post, depth)).astype(jnp.float32)
    pre_s = jnp.asarray([1, 0, 1, 0, 1, 0, 1, 0])
    post_s = jnp.asarray([0, 1, 0, 1, 0, 1])
    w2 = synapse_update(w, pre_s, post_s, pre_h, post_h, p, eta=10.0)
    assert float(w2.min()) >= 0.0 and float(w2.max()) <= 1.0
    # pre=0, post=1 columns potentiate (ltp only); pre=1, post=0 depress
    w3 = synapse_update(w, pre_s, post_s, pre_h, post_h, p, eta=0.01)
    dw = np.asarray(w3 - w)
    # pre fires on even rows; post fires on odd columns
    assert (dw[1::2][:, 1::2] >= 0).all()    # pre silent, post fired → LTP
    assert (dw[::2][:, ::2] <= 0).all()      # pre fired, post silent → LTD
    assert np.allclose(dw[::2][:, 1::2], 0)  # both fired → no update
    assert np.allclose(dw[1::2][:, ::2], 0)  # neither fired → no update


def test_nearest_vs_all_pairing_differ(key):
    p = STDPParams()
    w = jnp.full((4, 4), 0.5)
    pre_h = jnp.ones((4, 7), jnp.float32)     # dense history
    post_h = jnp.ones((4, 7), jnp.float32)
    pre_s = jnp.asarray([1, 1, 0, 0])
    post_s = jnp.asarray([0, 0, 1, 1])
    wn = synapse_update(w, pre_s, post_s, pre_h, post_h, p, pairing="nearest",
                        eta=0.1)
    wa = synapse_update(w, pre_s, post_s, pre_h, post_h, p, pairing="all",
                        eta=0.1)
    assert not np.allclose(np.asarray(wn), np.asarray(wa))
