"""Event-driven sparse backend parity: ops, engine scan, sharded, SNNs.

The sparse datapath must be *exactly* the dense reference wherever the
event lists are uncapped — the scatter-RMW sequence touches only the
slices the XOR pair gate could have made non-zero — and deterministically
truncated (highest-indexed events dropped) when ``max_events`` caps the
lists.  Pinned at every level the backend routes through:

  * ops:        ``sparse_weight_update`` / ``sparse_synapse_delta`` vs
                the dense ``repro.core.stdp`` formulas
  * engine:     jitted ``run_engine`` scan trajectories vs reference
  * sharded:    ``make_sharded_engine_step`` on a 1×1 mesh vs reference
  * networks:   2layer-SNN / DCSNN / CSNN full-trajectory parity
  * launcher:   ``repro.launch.train`` engine + snn modes run end-to-end
"""

import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import EngineConfig, init_engine, run_engine
from repro.core.stdp import STDPParams, magnitudes_depth_major, synapse_update
from repro.kernels.itp_sparse.events import spike_events
from repro.kernels.itp_sparse.ops import sparse_synapse_delta, sparse_weight_update
from repro.models import snn

DEPTH = 7


def _rand_case(key, n_pre=12, n_post=9, density=0.4):
    ks = jax.random.split(key, 5)
    w = jax.random.uniform(ks[0], (n_pre, n_post), minval=0.2, maxval=0.8)
    pre = jax.random.bernoulli(ks[1], density, (n_pre,)).astype(jnp.float32)
    post = jax.random.bernoulli(ks[2], density, (n_post,)).astype(jnp.float32)
    pre_h = jax.random.bernoulli(ks[3], 0.3, (n_pre, DEPTH)).astype(jnp.float32)
    post_h = jax.random.bernoulli(ks[4], 0.3, (n_post, DEPTH)).astype(jnp.float32)
    return w, pre, post, pre_h, post_h


def _magnitudes(hist_nd, amplitude, tau, pairing):
    return magnitudes_depth_major(hist_nd.T, amplitude, tau, pairing=pairing, compensate=True)


# ---------------------------------------------------------------------------
# Ops level
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pairing", ["nearest", "all"])
@pytest.mark.parametrize("density", [0.05, 0.4, 1.0])
def test_sparse_weight_update_matches_dense(key, pairing, density):
    p = STDPParams()
    w, pre, post, pre_h, post_h = _rand_case(key, density=density)
    dense = synapse_update(w, pre, post, pre_h, post_h, p, pairing=pairing, eta=1 / 16)
    ltp = _magnitudes(pre_h, p.a_plus, p.tau_plus, pairing)
    ltd = _magnitudes(post_h, p.a_minus, p.tau_minus, pairing)
    sparse = sparse_weight_update(w, pre, post, ltp, ltd, eta=1 / 16)
    np.testing.assert_allclose(np.asarray(sparse), np.asarray(dense), rtol=1e-6, atol=1e-7)


def test_sparse_synapse_delta_matches_dense_formula(key):
    p = STDPParams()
    _, pre, post, pre_h, post_h = _rand_case(key)
    ltp = _magnitudes(pre_h, p.a_plus, p.tau_plus, "nearest")
    ltd = _magnitudes(post_h, p.a_minus, p.tau_minus, "nearest")
    ltp_term = (1.0 - pre[:, None]) * ltp[:, None] * post[None, :]
    ltd_term = pre[:, None] * (1.0 - post[None, :]) * ltd[None, :]
    want = ltp_term - ltd_term
    got = sparse_synapse_delta(pre, post, ltp, ltd)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7)


def test_sparse_update_overflow_truncates_highest_indices(key):
    """Capped lists keep the first ``max_events`` active indices: the
    update equals the dense formula with the dropped (highest-indexed)
    spikes masked OUT of the scatter sides but still present in the
    magnitudes' pair gate."""
    p = STDPParams()
    cap = 2
    w, pre, post, pre_h, post_h = _rand_case(key, density=0.9)
    ltp = _magnitudes(pre_h, p.a_plus, p.tau_plus, "nearest")
    ltd = _magnitudes(post_h, p.a_minus, p.tau_minus, "nearest")

    def trunc(spikes):
        idx, _ = spike_events(spikes, cap)
        kept = jnp.zeros_like(spikes).at[idx].set(1.0, mode="drop")
        return spikes * kept

    pre_t, post_t = trunc(pre), trunc(post)
    ltp_term = (1.0 - pre[:, None]) * ltp[:, None] * post_t[None, :]
    ltd_term = pre_t[:, None] * (1.0 - post[None, :]) * ltd[None, :]
    want = jnp.clip(w + (1 / 16) * (ltp_term - ltd_term), 0.0, 1.0)
    got = sparse_weight_update(w, pre, post, ltp, ltd, eta=1 / 16, max_events=cap)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-7)


# ---------------------------------------------------------------------------
# Engine scan level
# ---------------------------------------------------------------------------


def _run_engine_pair(
    key,
    backend,
    *,
    rule="itp",
    pairing="nearest",
    quantise=False,
    density=0.35,
    max_events=None,
    t=48,
):
    cfg = EngineConfig(
        n_pre=24,
        n_post=16,
        rule=rule,
        backend=backend,
        pairing=pairing,
        quantise=quantise,
        max_events=max_events,
    )
    state = init_engine(key, cfg)
    spike_key = jax.random.fold_in(key, 7)
    train = jax.random.bernoulli(spike_key, density, (t, cfg.n_pre)).astype(jnp.float32)
    return run_engine(state, train, cfg)


@pytest.mark.parametrize("pairing", ["nearest", "all"])
@pytest.mark.parametrize("quantise", [False, True])
def test_engine_sparse_matches_reference(key, pairing, quantise):
    for density in (0.02, 0.3, 0.9):
        ref_st, ref_post = _run_engine_pair(
            key, "reference", pairing=pairing, quantise=quantise, density=density
        )
        sp_st, sp_post = _run_engine_pair(
            key, "sparse", pairing=pairing, quantise=quantise, density=density
        )
        np.testing.assert_allclose(np.asarray(ref_st.w), np.asarray(sp_st.w), rtol=1e-5, atol=1e-6)
        np.testing.assert_array_equal(np.asarray(ref_post), np.asarray(sp_post))


def test_engine_sparse_itp_nocomp_matches_reference(key):
    ref_st, ref_post = _run_engine_pair(key, "reference", rule="itp_nocomp")
    sp_st, sp_post = _run_engine_pair(key, "sparse", rule="itp_nocomp")
    np.testing.assert_allclose(np.asarray(ref_st.w), np.asarray(sp_st.w), rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ref_post), np.asarray(sp_post))


def test_engine_sparse_silent_raster_is_noop(key):
    cfg = EngineConfig(n_pre=8, n_post=6, backend="sparse")
    state = init_engine(key, cfg)
    train = jnp.zeros((20, cfg.n_pre))
    out, post = run_engine(state, train, cfg)
    np.testing.assert_array_equal(np.asarray(out.w), np.asarray(state.w))
    assert not np.asarray(post).any()


def test_engine_sparse_capped_is_deterministic_and_bounded(key):
    a_st, a_post = _run_engine_pair(key, "sparse", density=0.8, max_events=3)
    b_st, b_post = _run_engine_pair(key, "sparse", density=0.8, max_events=3)
    np.testing.assert_array_equal(np.asarray(a_st.w), np.asarray(b_st.w))
    np.testing.assert_array_equal(np.asarray(a_post), np.asarray(b_post))
    w = np.asarray(a_st.w)
    assert np.isfinite(w).all() and (w >= 0.0).all() and (w <= 1.0).all()


def test_engine_max_events_validation():
    with pytest.raises(ValueError, match="max_events"):
        EngineConfig(max_events=0)
    with pytest.raises(ValueError, match="max_events"):
        EngineConfig(max_events=-3)
    EngineConfig(max_events=1)  # valid
    EngineConfig(max_events=None)  # uncapped


# ---------------------------------------------------------------------------
# Sharded engine level (1×1 mesh on the single CPU device)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("max_events", [None, 5])
def test_sharded_engine_sparse_parity_single_device(key, max_events):
    from repro.core.engine_sharded import make_sharded_engine_step, shard_engine_state

    cfg = EngineConfig(n_pre=24, n_post=16, backend="sparse", max_events=max_events)
    state = init_engine(key, cfg)
    t = 40
    spike_key = jax.random.fold_in(key, 7)
    train = jax.random.bernoulli(spike_key, 0.3, (t, cfg.n_pre)).astype(jnp.float32)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        st = shard_engine_state(state, mesh)
        step = make_sharded_engine_step(cfg, mesh)
        posts = []
        for i in range(t):
            st, p = step(st, train[i])
            posts.append(np.asarray(p))
    ref_st, ref_post = run_engine(state, train, cfg)
    np.testing.assert_allclose(np.asarray(ref_st.w), np.asarray(st.w), rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ref_post), np.stack(posts))


# ---------------------------------------------------------------------------
# Network level: the paper's three SNNs
# ---------------------------------------------------------------------------


def _snn_cfg(maker, shape, backend, **kw):
    cfg = maker("itp", **kw)
    return dataclasses.replace(cfg, input_shape=shape, backend=backend)


def _run_snn(cfg, shape, t=10, batch=2, rate=0.25):
    state = snn.init_snn(jax.random.PRNGKey(1), cfg, batch)
    raster_key = jax.random.PRNGKey(3)
    raster = jax.random.bernoulli(raster_key, rate, (t, batch) + shape).astype(jnp.float32)
    return snn.run_snn(state, raster, cfg, train=True)


@pytest.mark.parametrize(
    "maker,shape,kw",
    [
        (snn.mnist_2layer, (14, 14, 1), {"n_hidden": 30}),
        (snn.fmnist_dcsnn, (12, 12, 1), {}),
        (snn.fault_csnn, (64, 2), {"length": 64}),
    ],
    ids=["2layer", "dcsnn", "csnn"],
)
def test_snn_sparse_matches_reference(maker, shape, kw):
    ref_st, ref_out = _run_snn(_snn_cfg(maker, shape, "reference", **kw), shape)
    sp_st, sp_out = _run_snn(_snn_cfg(maker, shape, "sparse", **kw), shape)
    for wr, ws in zip(ref_st.weights, sp_st.weights):
        np.testing.assert_allclose(np.asarray(wr), np.asarray(ws), rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ref_out), np.asarray(sp_out))


def test_snn_sparse_capped_is_deterministic():
    shape = (14, 14, 1)
    cfg = _snn_cfg(snn.mnist_2layer, shape, "sparse", n_hidden=30)
    cfg = dataclasses.replace(cfg, max_events=8)
    a, _ = _run_snn(cfg, shape)
    b, _ = _run_snn(cfg, shape)
    for wa, wb in zip(a.weights, b.weights):
        np.testing.assert_array_equal(np.asarray(wa), np.asarray(wb))
        assert np.isfinite(np.asarray(wa)).all()


def test_snn_max_events_validation():
    with pytest.raises(ValueError, match="max_events"):
        snn.mnist_2layer("itp", backend="sparse", max_events=0)
    snn.mnist_2layer("itp", backend="sparse", max_events=4)  # valid


# ---------------------------------------------------------------------------
# Launcher level
# ---------------------------------------------------------------------------


def test_launcher_engine_mode_sparse_smoke():
    from repro.launch.train import run_engine_training

    ns = argparse.Namespace(
        rule="itp",
        backend="sparse",
        engine_pre=32,
        engine_post=32,
        replicas=2,
        steps=8,
        engine_rate=0.3,
        max_events=8,
    )
    summary = run_engine_training(ns)
    assert summary["backend"] == "sparse"
    assert summary["sops_per_s"] > 0


def test_launcher_snn_mode_sparse_smoke():
    from repro.launch.train import run_snn_training

    ns = argparse.Namespace(
        rule="itp",
        backend="sparse",
        snn="2layer-snn",
        steps=4,
        batch=2,
        engine_rate=0.3,
        max_events=None,
    )
    summary = run_snn_training(ns)
    assert summary["backend"] == "sparse"
