"""Reward-modulated ITP-STDP (rule="mstdp") rides every backend for free.

The ISSUE-9 payoff test: mstdp is written against the slim
:class:`Rank1Rule` protocol only (state machine + readout + modulated
magnitudes — ~100 LoC, no kernel code), yet runs on reference /
fused_interpret / sparse, through the sharded engine and the
train-to-accuracy trainer, with zero edits to the engine or model files.
Also pins the eligibility-word arithmetic (shift decay, saturation, the
/128 fixed-point read) and the reward semantics (r=0 freezes learning,
r<0 flips the update direction).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import history as H
from repro.core.engine import EngineConfig, init_engine, run_engine
from repro.plasticity import MSTDP, MSTDPRule, MSTDPState, get_rule
from repro.plasticity.base import RULES
from repro.plasticity.mstdp import ELIG_INJECT, ELIG_MAX

MSTDP_BACKENDS = ("reference", "fused_interpret", "sparse")
T_STEPS = 32


def _run(key, backend, **kw):
    cfg = EngineConfig(n_pre=16, n_post=8, rule="mstdp", backend=backend, **kw)
    state = init_engine(key, cfg)
    train = jax.random.bernoulli(key, 0.3, (T_STEPS, cfg.n_pre))
    final, post = run_engine(state, train, cfg)
    return state, final, post


@pytest.fixture
def reward(request):
    """Temporarily re-register mstdp with a different reward scalar."""
    RULES["mstdp"] = MSTDPRule(reward=request.param)
    yield request.param
    RULES["mstdp"] = MSTDP


# ---------------------------------------------------------------------------
# State machine: the eligibility word
# ---------------------------------------------------------------------------


def test_eligibility_word_shift_decay_and_saturation():
    rule = get_rule("mstdp")
    state = rule.init_state(4, 7)
    assert isinstance(state, MSTDPState)
    assert state.elig.dtype == jnp.uint8
    ones = jnp.ones((4,), jnp.float32)
    # repeated spiking saturates at ELIG_MAX and never wraps the word
    for _ in range(10):
        state = rule.step(state, ones, depth=7)
    np.testing.assert_array_equal(np.asarray(state.elig), ELIG_MAX)
    # silence decays by exactly one right shift per step
    state = rule.step(state, jnp.zeros((4,)), depth=7)
    np.testing.assert_array_equal(np.asarray(state.elig), ELIG_MAX >> 1)
    state = rule.step(state, jnp.zeros((4,)), depth=7)
    np.testing.assert_array_equal(np.asarray(state.elig), ELIG_MAX >> 2)
    # a lone spike injects the fixed credit on top of the decayed word
    state = rule.step(state, ones, depth=7)
    np.testing.assert_array_equal(
        np.asarray(state.elig), (ELIG_MAX >> 3) + ELIG_INJECT
    )


def test_readout_is_one_extra_register_row():
    rule = get_rule("mstdp")
    state = rule.init_state(6, 5)
    state = rule.step(state, jnp.ones((6,)), depth=5)
    arr = rule.readout(state)
    assert arr.shape == (6, 6)  # depth history rows + 1 eligibility row
    assert arr.dtype == jnp.uint8
    np.testing.assert_array_equal(
        np.asarray(arr[:-1]), np.asarray(H.registers_depth_major(state.hist))
    )
    np.testing.assert_array_equal(np.asarray(arr[-1]), np.asarray(state.elig))
    np.testing.assert_array_equal(
        np.asarray(rule.last_spikes(state)), np.ones(6, np.float32)
    )


# ---------------------------------------------------------------------------
# Every declared backend, for free
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", MSTDP_BACKENDS)
def test_mstdp_runs_on_every_backend(key, backend):
    state0, final, _ = _run(key, backend)
    w = np.asarray(final.w)
    assert np.isfinite(w).all()
    assert (w >= 0.0).all() and (w <= 1.0).all()
    assert not np.array_equal(w, np.asarray(state0.w))
    assert final.pre_hist.elig.dtype == jnp.uint8


@pytest.mark.parametrize("backend", ("fused_interpret", "sparse"))
def test_mstdp_backends_match_reference(key, backend):
    _, ref, post_ref = _run(key, "reference")
    _, got, post_got = _run(key, backend)
    np.testing.assert_allclose(np.asarray(got.w), np.asarray(ref.w),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(post_got), np.asarray(post_ref))


@pytest.mark.parametrize("backend", ("reference", "fused_interpret"))
def test_mstdp_crosses_sharded_engine(key, backend):
    from repro.core.engine_sharded import (make_sharded_engine_step,
                                           shard_engine_state)

    cfg = EngineConfig(n_pre=16, n_post=8, rule="mstdp", backend=backend)
    state0 = init_engine(key, cfg)
    train = jax.random.bernoulli(key, 0.3, (16, cfg.n_pre))
    ref_state, ref_post = run_engine(state0, train, cfg)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        st = shard_engine_state(init_engine(key, cfg), mesh)
        step = make_sharded_engine_step(cfg, mesh)
        posts = []
        for t in range(train.shape[0]):
            st, post = step(st, train[t])
            posts.append(np.asarray(post))
    np.testing.assert_allclose(np.asarray(ref_state.w), np.asarray(st.w),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ref_post), np.stack(posts))


# ---------------------------------------------------------------------------
# Reward semantics
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("reward", [0.0], indirect=True)
def test_zero_reward_freezes_learning(key, reward):
    state0, final, _ = _run(key, "reference")
    np.testing.assert_array_equal(np.asarray(final.w), np.asarray(state0.w))


@pytest.mark.parametrize("reward", [-1.0], indirect=True)
def test_negative_reward_flips_update_direction(key, reward):
    state0, neg, _ = _run(key, "reference")
    RULES["mstdp"] = MSTDP  # reward=+1 for the comparison run
    _, pos, _ = _run(key, "reference")
    RULES["mstdp"] = MSTDPRule(reward=-1.0)  # fixture teardown expects it
    dw_pos = np.asarray(pos.w) - np.asarray(state0.w)
    dw_neg = np.asarray(neg.w) - np.asarray(state0.w)
    moved = dw_pos != 0.0
    assert moved.any()
    # away from the clip rails the negated reward negates the trajectory's
    # first-step delta; over a scan the paths diverge, so pin directions
    assert (np.sign(dw_neg[moved]) != np.sign(dw_pos[moved])).mean() > 0.5


@pytest.mark.parametrize("reward", [0.5], indirect=True)
def test_reward_is_static_replace_field(key, reward):
    assert get_rule("mstdp").reward == 0.5
    assert dataclasses.replace(MSTDP, reward=0.25).reward == 0.25


# ---------------------------------------------------------------------------
# Through the trainer (network level)
# ---------------------------------------------------------------------------


def test_mstdp_through_stdp_trainer():
    from repro.launch import cli
    from repro.models import snn
    from repro.train.stdp_trainer import TrainerConfig, train_to_accuracy

    sampler, n_classes = cli.sampler_for("2layer-snn")
    cfg = snn.mnist_2layer("mstdp", n_hidden=16, backend="fused_interpret",
                           theta_plus=0.05, hard_wta=True)
    tcfg = TrainerConfig(epochs=1, batches_per_epoch=2, batch=4, t_steps=10,
                         assign_batches=2, eval_batches=2)
    r = train_to_accuracy(cfg, sampler, n_classes, tcfg)
    assert len(r["accuracy_curve"]) == 1
    assert np.isfinite(r["final_accuracy"])
    assert r["sim_steps"] == 2 * 10
