"""Checkpointing + fault tolerance: integrity, atomicity, deterministic
restart, straggler watchdog."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (AsyncCheckpointer, latest_checkpoint,
                              list_checkpoints, prune_checkpoints,
                              restore_checkpoint, save_checkpoint)
from repro.distributed.fault_tolerance import (FailureInjector, RunnerConfig,
                                               TrainingRunner, Watchdog)


def _tree(key):
    return {"w": jax.random.normal(key, (8, 8)),
            "opt": {"mu": jnp.zeros((8, 8)), "step": jnp.asarray(3)}}


def test_save_restore_roundtrip(key, tmp_path):
    t = _tree(key)
    save_checkpoint(str(tmp_path), 7, t)
    r = restore_checkpoint(str(tmp_path), 7, jax.tree_util.tree_map(
        jnp.zeros_like, t))
    for a, b in zip(jax.tree_util.tree_leaves(t),
                    jax.tree_util.tree_leaves(r)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checksum_detects_corruption(key, tmp_path):
    t = _tree(key)
    path = save_checkpoint(str(tmp_path), 1, t)
    victim = os.path.join(path, "w.npy")
    arr = np.load(victim)
    arr[0, 0] += 1.0
    np.save(victim, arr)
    with pytest.raises(IOError, match="checksum"):
        restore_checkpoint(str(tmp_path), 1,
                           jax.tree_util.tree_map(jnp.zeros_like, t))


def test_latest_and_prune(key, tmp_path):
    t = _tree(key)
    for s in (1, 5, 9, 12):
        save_checkpoint(str(tmp_path), s, t)
    assert latest_checkpoint(str(tmp_path)) == 12
    prune_checkpoints(str(tmp_path), keep=2)
    assert list_checkpoints(str(tmp_path)) == [9, 12]


def test_partial_write_ignored(key, tmp_path):
    t = _tree(key)
    save_checkpoint(str(tmp_path), 3, t)
    # simulate a crash mid-save: tmp dir without manifest
    os.makedirs(str(tmp_path / "step_000000009.tmp"))
    # and a committed-looking dir without manifest
    os.makedirs(str(tmp_path / "step_000000010"))
    assert latest_checkpoint(str(tmp_path)) == 3


def test_async_checkpointer(key, tmp_path):
    ck = AsyncCheckpointer(str(tmp_path), keep=2)
    t = _tree(key)
    for s in (2, 4, 6):
        ck.save(s, t)
    ck.wait()
    assert latest_checkpoint(str(tmp_path)) == 6
    assert len(list_checkpoints(str(tmp_path))) <= 2


# ---------------------------------------------------------------------------
# Watchdog
# ---------------------------------------------------------------------------

def test_watchdog_flags_straggler():
    times = iter([float(i) for i in range(100)])
    now = [0.0]

    def clock():
        return now[0]

    wd = Watchdog(threshold=3.0, clock=clock)
    for step in range(12):
        wd.start()
        now[0] += 1.0          # normal step: 1s
        assert not wd.stop(step)
    wd.start()
    now[0] += 10.0             # straggler: 10s > 3 × median(1s)
    assert wd.stop(12)
    assert wd.stragglers[0][0] == 12


# ---------------------------------------------------------------------------
# Deterministic restart
# ---------------------------------------------------------------------------

def _counter_step(state, batch):
    new = {"x": state["x"] + batch["v"], "n": state["n"] + 1}
    return new, {"loss": jnp.sum(new["x"])}


def _batch_fn(step):
    return {"v": jnp.full((4,), float(step + 1))}


def test_runner_restart_is_deterministic(tmp_path):
    """Failure + restore + replay ≡ an uninterrupted run (step-keyed data)."""
    state0 = {"x": jnp.zeros((4,)), "n": jnp.asarray(0)}
    clean = TrainingRunner(
        RunnerConfig(ckpt_dir=str(tmp_path / "clean"), ckpt_every=3),
        _counter_step, _batch_fn)
    s_clean = clean.run(state0, 10)

    faulty = TrainingRunner(
        RunnerConfig(ckpt_dir=str(tmp_path / "faulty"), ckpt_every=3),
        _counter_step, _batch_fn)
    s_faulty = faulty.run(state0, 10, FailureInjector({7}))
    assert faulty.restarts == 1
    np.testing.assert_array_equal(np.asarray(s_clean["x"]),
                                  np.asarray(s_faulty["x"]))
    assert int(s_faulty["n"]) == 10


def test_runner_gives_up_after_max_restarts(tmp_path):
    runner = TrainingRunner(
        RunnerConfig(ckpt_dir=str(tmp_path), ckpt_every=100,
                     max_restarts=2),
        _counter_step, _batch_fn)
    state0 = {"x": jnp.zeros((4,)), "n": jnp.asarray(0)}
    injector = FailureInjector({3})

    class AlwaysFail(FailureInjector):
        def maybe_fail(self, step):
            if step == 3:
                raise RuntimeError("persistent failure")

    with pytest.raises(RuntimeError, match="max_restarts"):
        runner.run(state0, 10, AlwaysFail())


def test_manifest_schema(key, tmp_path):
    t = _tree(key)
    path = save_checkpoint(str(tmp_path), 2, t, extra={"mesh": "16x16"})
    with open(os.path.join(path, "manifest.json")) as f:
        m = json.load(f)
    assert m["step"] == 2
    assert m["extra"]["mesh"] == "16x16"
    names = {l["name"] for l in m["leaves"]}
    assert "w" in names and any("mu" in n for n in names)
    for leaf in m["leaves"]:
        assert set(leaf) == {"name", "shape", "dtype", "sha256"}
