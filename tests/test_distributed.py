"""Sharding rules, po2 compression, and multi-device semantics.

Multi-device tests run in a subprocess with
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` so the main test
process keeps the single default CPU device.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.compression import compression_error
from repro.distributed.sharding import (kv_cache_spec, logical_to_spec,
                                        param_spec_for)
from repro.kernels.po2_quant.ref import po2_encode_ref, po2_roundtrip_ref


class FakeMesh:
    """Shape-only stand-in so sharding rules are testable on 1 device."""

    def __init__(self, **shape):
        self.shape = shape
        self.axis_names = tuple(shape)


# ---------------------------------------------------------------------------
# Rule resolution
# ---------------------------------------------------------------------------

def test_logical_to_spec_divisibility_guard():
    mesh = FakeMesh(data=16, model=16)
    spec = logical_to_spec(("fsdp", "tp"), (100, 256), mesh)
    assert spec == P(None, "model")        # 100 % 16 != 0 → dropped
    spec = logical_to_spec(("fsdp", "tp"), (160, 256), mesh)
    assert spec == P("data", "model")


def test_logical_to_spec_right_alignment():
    mesh = FakeMesh(data=4, model=4)
    spec = logical_to_spec(("fsdp", "tp"), (7, 16, 16), mesh)
    assert spec == P(None, "data", "model")   # leading stack dim replicates


def test_param_rules_dense():
    mesh = FakeMesh(data=16, model=16)
    cfg = get_config("yi-9b")
    assert param_spec_for("blocks/attn/wq", (4096, 4096), cfg, mesh) \
        == P("data", "model")
    assert param_spec_for("blocks/attn/wo", (4096, 4096), cfg, mesh) \
        == P("model", "data")
    assert param_spec_for("blocks/norm1/scale", (4096,), cfg, mesh) == P()


def test_param_rules_moe_ep_vs_tp():
    import dataclasses
    mesh = FakeMesh(data=16, model=16)
    phi = get_config("phi3.5-moe-42b-a6.6b")     # 16 experts % 16 == 0 → EP
    spec = param_spec_for("blocks/moe/gate", (16, 4096, 6400), phi, mesh)
    assert spec[0] == "model"                    # experts sharded
    qw = get_config("qwen2-moe-a2.7b")           # 60 padded → 64 → EP
    spec = param_spec_for("blocks/moe/gate", (64, 2048, 1408), qw, mesh)
    assert spec[0] == "model"
    # without padding, 60 % 16 != 0 → TP inside each expert
    qw_nopad = dataclasses.replace(qw, n_experts_padded=0)
    spec = param_spec_for("blocks/moe/gate", (60, 2048, 1408), qw_nopad, mesh)
    assert spec[0] is None
    assert spec[2] == "model"


def test_embed_tok_rule_drops_fsdp_on_pod_mesh():
    cfg = get_config("yi-9b")
    single = FakeMesh(data=16, model=16)
    multi = FakeMesh(pod=2, data=16, model=16)
    assert param_spec_for("embed/tok", (64000, 4096), cfg, single) \
        == P("model", "data")
    assert param_spec_for("embed/tok", (64000, 4096), cfg, multi) \
        == P("model", None)


def test_kv_cache_spec_preferences():
    mesh = FakeMesh(pod=2, data=16, model=16)
    # kv heads divide → heads on model, batch on (pod, data)
    s = kv_cache_spec((64, 128, 32768, 16, 128), mesh)
    assert s[3] == "model" and s[1] == ("pod", "data")
    # kv heads don't divide → sequence parallelism over model
    s = kv_cache_spec((64, 128, 32768, 40, 128), mesh)
    assert s[3] is None and s[2] in ("model", ("model",))
    # batch=1 latency decode → context over (data, model)
    s = kv_cache_spec((3, 1, 524288, 5, 64), mesh)
    assert s[1] is None and s[2] == ("data", "model")


# ---------------------------------------------------------------------------
# po2 compression
# ---------------------------------------------------------------------------

@settings(max_examples=100, deadline=None)
@given(x=st.floats(-1e10, 1e10, allow_nan=False, width=32))
def test_po2_wire_format_byte_range(x):
    c = int(po2_encode_ref(jnp.asarray(x, jnp.float32)))
    assert 0 <= c < 256                        # one byte on the wire


def test_po2_relative_error_bound(key):
    g = jax.random.normal(key, (10_000,)) * 1e-3
    err = float(compression_error({"g": g}))
    # log-space rounding: rms relative error ≈ 0.12, worst 2^0.5-1
    assert err < 0.25


def test_po2_signs_and_zeros(key):
    g = jnp.asarray([0.0, 1.5, -1.5, 3e-7, -3e-7])
    q = po2_roundtrip_ref(g)
    assert float(q[0]) == 0.0
    assert float(q[1]) > 0 > float(q[2])
    assert float(q[3]) > 0 > float(q[4])


# ---------------------------------------------------------------------------
# shard_map version compat
# ---------------------------------------------------------------------------

def test_shard_map_compat_single_device():
    """The shim runs on whichever shard_map API the installed jax has.

    Covers the ``axis_names`` translation (→ ``auto`` on the
    ``jax.experimental`` API) — the call shape MULTIDEV_SCRIPT uses —
    and the plain fully-manual form the sharded engine uses.
    """
    from repro.distributed.sharding import shard_map_compat

    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    x = jnp.arange(8, dtype=jnp.float32).reshape(1, 8)
    out = jax.jit(shard_map_compat(
        lambda g: jax.lax.pmean(g, "pod"),
        mesh=mesh, in_specs=P("pod"), out_specs=P(),
        axis_names={"pod"}))(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6)

    out2 = jax.jit(shard_map_compat(
        lambda g: jax.lax.pmean(g, "pod"),
        mesh=mesh, in_specs=P("pod"), out_specs=P()))(x)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(x), rtol=1e-6)


def test_train_step_multipod_traces_on_this_toolchain(key):
    """The multi-pod train-step branch must trace on the pinned jax.

    Regression for the lint suite's first real catch (rule R1):
    ``train_step`` called ``jax.shard_map`` directly, which does not
    exist on jax 0.4.37 — the pod branch raised ``AttributeError`` the
    moment a mesh with a ``pod`` axis was passed.  Tracing abstractly
    via ``eval_shape`` exercises exactly that branch without running it.
    """
    from repro.configs import get_smoke_config
    from repro.distributed.sharding import use_mesh
    from repro.models import transformer
    from repro.train import OptimizerConfig, TrainConfig, make_train_step
    from repro.train.optimizer import init_opt_state

    cfg = get_smoke_config("qwen3-0.6b")
    opt_cfg = OptimizerConfig(total_steps=2)
    mesh = jax.make_mesh((1, 1, 1), ("pod", "data", "model"))
    with use_mesh(mesh):
        step = make_train_step(cfg, opt_cfg, TrainConfig(remat="none"), mesh)
        params = jax.eval_shape(
            lambda k: transformer.init_model(k, cfg), key)
        opt = jax.eval_shape(init_opt_state, params)
        batch = {
            "tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32),
            "labels": jax.ShapeDtypeStruct((2, 16), jnp.int32),
        }
        new_params, new_opt, metrics = jax.eval_shape(step, params, opt,
                                                      batch)
    assert metrics["loss"].shape == ()
    assert jax.tree_util.tree_structure(new_params) \
        == jax.tree_util.tree_structure(params)


# ---------------------------------------------------------------------------
# Multi-device semantics (subprocess; 8 forced host devices)
# ---------------------------------------------------------------------------

MULTIDEV_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.distributed.compression import pod_mean_tree
    from repro.distributed.sharding import shard_map_compat
    from repro.kernels.po2_quant.ref import po2_roundtrip_ref

    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    x = jnp.arange(16, dtype=jnp.float32).reshape(2, 8)   # pod-major rows

    def f(g):
        return pod_mean_tree({"g": g}, compress=True)["g"]

    out = jax.jit(shard_map_compat(
        f, mesh=mesh, in_specs=P("pod"), out_specs=P(),
        axis_names={"pod"}))(x)
    # expected: mean over pods of po2-quantised rows
    want = np.mean(np.asarray(po2_roundtrip_ref(x)).reshape(2, 1, 8),
                   axis=0)
    np.testing.assert_allclose(np.asarray(out), want, rtol=1e-6)

    # uncompressed path = plain mean
    def g(gr):
        return pod_mean_tree({"g": gr}, compress=False)["g"]
    out2 = jax.jit(shard_map_compat(
        g, mesh=mesh, in_specs=P("pod"), out_specs=P(),
        axis_names={"pod"}))(x)
    np.testing.assert_allclose(np.asarray(out2),
                               np.asarray(x).reshape(2, 1, 8).mean(0),
                               rtol=1e-6)
    print("MULTIDEV_OK")
""")


@pytest.mark.slow
@pytest.mark.multidevice
def test_pod_mean_semantics_multidevice():
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SCRIPT],
                       capture_output=True, text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "MULTIDEV_OK" in r.stdout, r.stdout + r.stderr


SHARDED_TRAIN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.configs import get_smoke_config
    from repro.distributed.sharding import use_mesh
    from repro.train import (OptimizerConfig, TrainConfig, init_training,
                             make_train_step)

    cfg = get_smoke_config("qwen3-0.6b")
    opt_cfg = OptimizerConfig(total_steps=4)

    def run(mesh):
        with use_mesh(mesh):
            params, opt = init_training(jax.random.PRNGKey(0), cfg, opt_cfg,
                                        mesh)
            step = jax.jit(make_train_step(cfg, opt_cfg,
                                           TrainConfig(remat="none"), mesh))
            batch = {
                "tokens": jnp.tile(jnp.arange(16, dtype=jnp.int32), (8, 1)),
                "labels": jnp.tile(jnp.arange(16, dtype=jnp.int32), (8, 1)),
            }
            for _ in range(2):
                params, opt, m = step(params, opt, batch)
            return float(m["loss"])

    l_single = run(jax.make_mesh((2, 2), ("data", "model")))
    l_multi = run(jax.make_mesh((2, 2, 2), ("pod", "data", "model")))
    # same data, same init → pod-compressed run must track closely
    assert abs(l_single - l_multi) / l_single < 0.05, (l_single, l_multi)
    print("TRAIN_OK", l_single, l_multi)
""")


@pytest.mark.slow
@pytest.mark.multidevice
def test_sharded_train_single_vs_multipod():
    r = subprocess.run([sys.executable, "-c", SHARDED_TRAIN_SCRIPT],
                       capture_output=True, text=True, timeout=560,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "TRAIN_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


# ---------------------------------------------------------------------------
# Sharding profiles (§Perf cell 1)
# ---------------------------------------------------------------------------

def test_sharding_profiles():
    from repro.distributed.sharding import use_sharding_profile
    mesh = FakeMesh(data=16, model=16)
    cfg = get_config("qwen3-0.6b")
    shape = (1024, 3072)   # an mlp/gate-like weight
    with use_sharding_profile("fsdp"):
        assert param_spec_for("blocks/mlp/gate", shape, cfg, mesh) \
            == P("data", "model")
    with use_sharding_profile("replicated"):
        assert param_spec_for("blocks/mlp/gate", shape, cfg, mesh) \
            == P(None, "model")
    with use_sharding_profile("dp"):
        spec = param_spec_for("blocks/mlp/gate", shape, cfg, mesh)
        assert all(s is None for s in spec)   # fully replicated
    with use_sharding_profile("dp_zero3"):
        # weights shard over the compute-idle model axis
        assert param_spec_for("blocks/mlp/gate", shape, cfg, mesh) \
            == P("model", None)


def test_dp_profile_batch_axes():
    from repro.distributed.sharding import batch_axes, use_sharding_profile
    mesh = FakeMesh(data=16, model=16)
    with use_sharding_profile("dp"):
        assert batch_axes(mesh) == ("data", "model")
    with use_sharding_profile("fsdp"):
        assert batch_axes(mesh) == ("data",)


# ---------------------------------------------------------------------------
# Sharded-engine parity (fast, single-device mesh — no subprocess)
# ---------------------------------------------------------------------------
# The forced-8-device subprocess variant below is known-hanging (ROADMAP);
# these run the same shard_map program on a 1×1 mesh over the default CPU
# device, so the collective schedule and the per-tile update path (incl.
# the fused Pallas kernel via the interpreter) are exercised in-process.

@pytest.mark.parametrize("backend,rule", [
    ("reference", "itp"),
    ("reference", "exact"),
    ("fused_interpret", "itp"),
    ("fused_interpret", "itp_nocomp"),
    # counter rules on the fused path: the (n,) uint8 counter word crosses
    # shard_map exactly like the packed history words (axis-0 sharded)
    ("fused_interpret", "exact"),
    ("fused_interpret", "linear"),
    ("fused_interpret", "imstdp"),
])
def test_sharded_engine_parity_single_device(key, backend, rule):
    from repro.core.engine import EngineConfig, init_engine, run_engine
    from repro.core.engine_sharded import (make_sharded_engine_step,
                                           shard_engine_state)

    cfg = EngineConfig(n_pre=16, n_post=8, eta=0.25, rule=rule,
                       backend=backend)
    state0 = init_engine(key, cfg)
    train = jax.random.bernoulli(key, 0.4, (20, 16))
    ref_state, ref_post = run_engine(state0, train, cfg)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        st = shard_engine_state(init_engine(key, cfg), mesh)
        step = make_sharded_engine_step(cfg, mesh)
        posts = []
        for t in range(train.shape[0]):
            st, post = step(st, train[t])
            posts.append(np.asarray(post))
    np.testing.assert_allclose(np.asarray(ref_state.w), np.asarray(st.w),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ref_post), np.stack(posts))


def test_sharded_engine_quantised_single_device(key):
    from repro.core.engine import EngineConfig, init_engine, run_engine
    from repro.core.engine_sharded import (make_sharded_engine_step,
                                           shard_engine_state)

    cfg = EngineConfig(n_pre=8, n_post=8, eta=0.5, quantise=True,
                       backend="fused_interpret")
    state0 = init_engine(key, cfg)
    train = jax.random.bernoulli(key, 0.4, (12, 8))
    ref_state, _ = run_engine(state0, train, cfg)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        st = shard_engine_state(init_engine(key, cfg), mesh)
        step = make_sharded_engine_step(cfg, mesh)
        for t in range(train.shape[0]):
            st, _ = step(st, train[t])
    np.testing.assert_allclose(np.asarray(ref_state.w), np.asarray(st.w),
                               rtol=1e-5, atol=1e-6)


SHARDED_ENGINE_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp
    import numpy as np
    from repro.core.engine import EngineConfig, init_engine, run_engine
    from repro.core.engine_sharded import (make_sharded_engine_step,
                                           shard_engine_state)

    cfg = EngineConfig(n_pre=16, n_post=8, eta=0.25)
    key = jax.random.PRNGKey(0)
    state0 = init_engine(key, cfg)
    train = jax.random.bernoulli(key, 0.4, (30, 16))

    # reference: single-device engine
    ref_state, ref_post = run_engine(state0, train, cfg)

    # distributed: 2-D sharded weights over a (2, 4) mesh
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    with mesh:
        st = shard_engine_state(init_engine(key, cfg), mesh)
        step = make_sharded_engine_step(cfg, mesh)
        posts = []
        for t in range(train.shape[0]):
            st, post = step(st, train[t])
            posts.append(np.asarray(post))
    np.testing.assert_allclose(np.asarray(ref_state.w), np.asarray(st.w),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ref_post), np.stack(posts))
    print("SHARDED_ENGINE_OK")
""")


@pytest.mark.slow
@pytest.mark.multidevice
def test_sharded_engine_matches_reference():
    """The paper's engine, 2-D weight-sharded over 8 devices, is bit-
    compatible with the single-device reference (DESIGN.md §4.1)."""
    r = subprocess.run([sys.executable, "-c", SHARDED_ENGINE_SCRIPT],
                       capture_output=True, text=True, timeout=420,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})
    assert "SHARDED_ENGINE_OK" in r.stdout, r.stdout[-1500:] + r.stderr[-1500:]
