"""repro-lint: the checker's own coverage.

Pins (a) the exact finding set each rule produces on the fixture tree
under ``tests/fixtures/lint/`` (one violation + a clean twin per rule),
(b) the ``--explain`` texts, (c) that the committed allowlist matches
the repo's *actual* baseline — empty for R1–R6 and R8, because the
satellite fixes removed every real violation — and (d) the jaxpr-audit contracts
on a slice of the matrix (the full matrix runs as the ``static_audit``
benchmark and in the CI gate).  The doc-lint layer (D1 snippet
execution, D2 link resolution) is covered on synthetic doc trees; the
repo's own snippets execute in the CI docs gate, not here.
"""
import json
import sys
from pathlib import Path

import pytest

from repro.analysis import (
    ALL_RULES,
    DOC_RULE_EXPLAIN,
    RULE_EXPLAIN,
    apply_allowlist,
    load_allowlist,
    render_allowlist,
    run_doclint,
    run_lint,
)
from repro.analysis.astlint import Finding
from repro.analysis.doclint import python_snippets

REPO_ROOT = Path(__file__).resolve().parent.parent
FIXTURE_ROOT = REPO_ROOT / "tests" / "fixtures" / "lint"
ALLOWLIST = REPO_ROOT / "tools" / "check_allowlist.json"


# ---------------------------------------------------------------------------
# Layer 1 on the fixture tree
# ---------------------------------------------------------------------------

# the full pinned finding set: exactly one violation per rule, and the
# clean twins sitting in the same directories stay silent
EXPECTED_FIXTURE_FINDINGS = {
    ("R1", "src/repro/core/r1_bad.py"),
    ("R2", "src/repro/core/r2_bad.py"),
    ("R3", "src/repro/kernels/fake/ops.py"),
    ("R4", "src/repro/core/r4_bad.py"),
    ("R5", "tests/test_r5_bad.py"),
    ("R6", "benchmarks/r6_bad.py"),
    ("R7", "src/repro/orphan_mod.py"),
    ("R8", "src/repro/core/r8_bad.py"),
}


def test_fixture_finding_set():
    findings = run_lint(FIXTURE_ROOT)
    assert {(f.rule, f.path) for f in findings} == EXPECTED_FIXTURE_FINDINGS
    # one finding per rule — the twins must not double-fire
    assert len(findings) == len(EXPECTED_FIXTURE_FINDINGS)


def test_fixture_clean_twins_are_silent():
    findings = run_lint(FIXTURE_ROOT)
    assert not [f for f in findings if "clean" in f.path]


def test_r7_allowlist_keys_by_module_name():
    (r7,) = run_lint(FIXTURE_ROOT, ["R7"])
    assert r7.key() == "repro.orphan_mod"
    assert r7.path == "src/repro/orphan_mod.py"


@pytest.mark.parametrize("rule", ALL_RULES)
def test_single_rule_selection(rule):
    findings = run_lint(FIXTURE_ROOT, [rule])
    assert {f.rule for f in findings} == {rule}


def test_unknown_rule_rejected():
    with pytest.raises(ValueError, match="unknown rules"):
        run_lint(FIXTURE_ROOT, ["R99"])


# ---------------------------------------------------------------------------
# --explain + CLI surface
# ---------------------------------------------------------------------------


def _tools_check():
    if str(REPO_ROOT) not in sys.path:
        sys.path.insert(0, str(REPO_ROOT))
    from tools import check

    return check


@pytest.mark.parametrize("rule", ALL_RULES)
def test_explain_text_pinned(rule, capsys):
    text = RULE_EXPLAIN[rule]
    assert text.startswith(f"{rule}: ")
    rc = _tools_check().main(["--explain", rule])
    assert rc == 0
    assert capsys.readouterr().out.strip() == text.strip()


def test_explain_first_lines():
    first = {r: RULE_EXPLAIN[r].splitlines()[0] for r in ALL_RULES}
    assert first == {
        "R1": "R1: `shard_map` may only be touched inside repro/distributed/sharding.py.",
        "R2": "R2: `repro.kernels.itp_*` packages are importable only by the plasticity",
        "R3": "R3: no literal `interpret=True/False` defaults in kernel ops wrappers.",
        "R4": "R4: one-argument `jnp.where(mask)` requires a static `size=`.",
        "R5": "R5: test modules import `_hypothesis_compat`, never `hypothesis` directly.",
        "R6": "R6: benchmarks write tracked BENCH_*.json via `bench_io.update_bench_json`.",
        "R7": "R7: every module under src/repro must be statically reachable from an",
        "R8": "R8: rule datapath hooks are called only inside repro/plasticity/.",
    }


def test_cli_fails_with_rule_and_location(capsys):
    argv = ["--lint", "--root", str(FIXTURE_ROOT), "--allowlist", "/dev/null"]
    rc = _tools_check().main(argv)
    out = capsys.readouterr().out
    assert rc == 1
    for rule, path in sorted(EXPECTED_FIXTURE_FINDINGS):
        assert f"{rule} {path}:" in out


def test_cli_clean_on_repo(capsys):
    rc = _tools_check().main(["--lint"])
    assert rc == 0, capsys.readouterr().out


# ---------------------------------------------------------------------------
# Allowlist semantics + committed baseline
# ---------------------------------------------------------------------------


def test_committed_allowlist_matches_repo_baseline():
    """The committed baseline IS the repo's current finding set: nothing
    new, nothing stale, and R1–R6 + R8 empty (the satellite fixes landed)."""
    findings = run_lint(REPO_ROOT)
    allow = load_allowlist(ALLOWLIST)
    new, stale = apply_allowlist(findings, allow)
    assert new == [], [f.render() for f in new]
    assert stale == []
    for rule in ("R1", "R2", "R3", "R4", "R5", "R6", "R8"):
        msg = f"{rule} baseline must stay empty — fix the violation instead of allowlisting"
        assert not allow.get(rule), msg
    expected = {"repro.configs.qwen3_0_6b", "repro.models.config"}
    assert {e["module"] for e in allow["R7"]} >= expected


def test_allowlist_requires_justification(tmp_path):
    p = tmp_path / "allow.json"
    p.write_text(json.dumps({"R7": [{"module": "repro.x", "justification": "  "}]}))
    with pytest.raises(ValueError, match="justification"):
        load_allowlist(p)
    p.write_text(json.dumps({"R1": [{"justification": "no file key"}]}))
    with pytest.raises(ValueError, match="missing 'file'"):
        load_allowlist(p)


def test_stale_entries_ratchet_down():
    findings = [Finding("R1", "src/a.py", 3, "msg", "src/a.py")]
    allow = {
        "R1": [
            {"file": "src/a.py", "justification": "known"},
            {"file": "src/gone.py", "justification": "fixed"},
        ],
    }
    new, stale = apply_allowlist(findings, allow)
    assert new == []
    assert stale == [("R1", "src/gone.py")]


def test_render_allowlist_roundtrip_keeps_justifications():
    findings = run_lint(FIXTURE_ROOT)
    prev = {"R7": [{"module": "repro.orphan_mod", "justification": "kept on purpose"}]}
    regen = json.loads(render_allowlist(findings, prev))
    (r7,) = regen["R7"]
    assert r7 == {"module": "repro.orphan_mod", "justification": "kept on purpose"}
    expected_r1 = [{"file": "src/repro/core/r1_bad.py", "justification": "TODO: justify or fix"}]
    assert regen["R1"] == expected_r1
    # regenerated baseline gates clean against the same findings
    new, stale = apply_allowlist(findings, regen)
    assert new == [] and stale == []


# ---------------------------------------------------------------------------
# Doc-lint layer — D1 snippet execution, D2 link resolution
# ---------------------------------------------------------------------------


def test_python_snippets_fences_and_line_numbers():
    text = "\n".join(
        [
            "intro",
            "```python",
            "x = 1",
            "y = 2",
            "```",
            "```bash",
            "ls",
            "```",
            "```python",
            "print(x)",
            "```",
        ]
    )
    assert python_snippets(text) == [(3, "x = 1\ny = 2"), (10, "print(x)")]


def test_doclint_clean_tree(tmp_path):
    docs = tmp_path / "docs"
    docs.mkdir()
    (tmp_path / "README.md").write_text("[arch](docs/a.md)\n```python\nprint(1 + 1)\n```\n")
    (docs / "a.md").write_text("back to the [README](../README.md)\n")
    assert run_doclint(tmp_path) == []


def test_doclint_d1_failing_snippet(tmp_path):
    (tmp_path / "README.md").write_text("# t\n```python\nraise SystemExit(3)\n```\n")
    findings = run_doclint(tmp_path)
    assert [(f.rule, f.path, f.line) for f in findings] == [("D1", "README.md", 3)]
    assert "snippet failed" in findings[0].message


def test_doclint_d1_only_python_fences_execute(tmp_path):
    (tmp_path / "README.md").write_text("```bash\nexit 1\n```\n```text\nnot code\n```\n")
    assert run_doclint(tmp_path) == []


def test_doclint_d2_broken_and_skipped_links(tmp_path):
    (tmp_path / "ok.md").write_text("x")
    (tmp_path / "README.md").write_text(
        "[gone](missing.md) [ok](ok.md) [ext](https://example.com/x.md)\n"
        "[anchor](#section) [anchored](ok.md#part)\n"
    )
    findings = run_doclint(tmp_path, execute=False)
    assert [(f.rule, f.line) for f in findings] == [("D2", 1)]
    assert "missing.md" in findings[0].message


def test_doclint_execute_false_skips_snippets(tmp_path):
    (tmp_path / "README.md").write_text("```python\nraise SystemExit(1)\n```\n")
    assert run_doclint(tmp_path, execute=False) == []


def test_repo_doc_links_resolve():
    """Every intra-repo link in README.md/docs/ points at a real file
    (snippet execution is the CI docs gate's job — too slow for here)."""
    assert run_doclint(REPO_ROOT, execute=False) == []


@pytest.mark.parametrize("rule", sorted(DOC_RULE_EXPLAIN))
def test_explain_covers_doc_rules(rule, capsys):
    rc = _tools_check().main(["--explain", rule])
    assert rc == 0
    assert capsys.readouterr().out.strip() == DOC_RULE_EXPLAIN[rule].strip()


def test_cli_docs_layer_reports_findings(tmp_path, capsys):
    (tmp_path / "README.md").write_text("[gone](missing.md)\n")
    rc = _tools_check().main(["--docs", "--root", str(tmp_path)])
    out = capsys.readouterr().out
    assert rc == 1
    assert "D2 README.md:1" in out
    assert "docs: 1 finding(s) — FAIL" in out


# ---------------------------------------------------------------------------
# Layer 2 — jaxpr audit
# ---------------------------------------------------------------------------


def test_audit_engine_cells_clean():
    from repro.analysis.jaxpr_audit import run_audit

    r = run_audit(kinds=("engine",))
    assert r["n_cells"] == 21  # 2 history × 4 + 3 counter × 3 + mstdp × 4
    bad = [c for c in r["cells"] if c["violations"]]
    assert not bad, bad
    # packed-register cells really carry uint8 through the graph
    for c in r["cells"]:
        if c["uint8_expected"]:
            assert c["has_uint8"], c
    # the counter reference cells read float magnitudes — no uint8 claim
    ref = [c for c in r["cells"] if c["backend"] == "reference" and c["rule"] == "exact"]
    assert ref and not ref[0]["uint8_expected"]


def test_audit_detects_trace_failure(monkeypatch):
    from repro.analysis import jaxpr_audit

    def boom(*a, **k):
        raise RuntimeError("synthetic trace failure")

    monkeypatch.setattr(jaxpr_audit, "engine_step", boom)
    cell = jaxpr_audit.audit_cell("itp", "reference", "engine")
    assert any("trace failed" in v for v in cell["violations"])


@pytest.mark.slow
def test_audit_full_matrix_clean():
    from repro.analysis.jaxpr_audit import run_audit

    r = run_audit()
    assert r["n_cells"] == 84  # 21 rule×backend cells × 4 kinds
    assert r["n_violating"] == 0, [c for c in r["cells"] if c["violations"]]


def test_bench_static_json_in_sync():
    """The tracked BENCH_static.json holds every valid cell of the matrix
    as traced on this toolchain, contract-clean."""
    from repro.analysis.jaxpr_audit import valid_cells

    path = REPO_ROOT / "BENCH_static.json"
    data = json.loads(path.read_text())["static_audit"]
    cells = {(c["rule"], c["backend"], c["kind"]) for c in data["cells"]}
    assert cells == set(valid_cells())
    assert data["n_violating"] == 0
    for c in data["cells"]:
        assert not c["violations"]
        assert not c.get("has_f64"), c
        if c.get("uint8_expected"):
            assert c.get("has_uint8"), c
