"""LM model stack: per-arch smoke tests + component references."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_NAMES, get_config, get_smoke_config, shapes_for
from repro.models import attention as attn
from repro.models import kvcache as kvc
from repro.models import transformer


# tier-1 keeps one representative small arch per smoke family; the full
# per-arch sweep is tier-2 (@slow)
FAST_ARCH = "qwen3-0.6b"
ARCH_PARAMS = [pytest.param(a, marks=[] if a == FAST_ARCH else
                            [pytest.mark.slow]) for a in ARCH_NAMES]


def _vis_kw(cfg, B):
    if cfg.family == "vlm":
        return {"vis_embed": jnp.ones((B, 8, cfg.vis_dim), jnp.float32) * 0.1}
    return {}


# ---------------------------------------------------------------------------
# Per-arch smoke tests (reduced configs, per the brief)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_arch_smoke_forward(key, arch):
    cfg = get_smoke_config(arch)
    params = transformer.init_model(key, cfg)
    B, S = 2, 32
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits, aux = transformer.forward(params, cfg, tokens=toks,
                                      **_vis_kw(cfg, B))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_arch_smoke_train_step(key, arch):
    """One forward/train step on CPU: shapes + finite loss + finite grads."""
    from repro.train import OptimizerConfig, TrainConfig, make_train_step
    from repro.train.optimizer import init_opt_state
    cfg = get_smoke_config(arch)
    params = transformer.init_model(key, cfg)
    opt = init_opt_state(params)
    step = jax.jit(make_train_step(cfg, OptimizerConfig(total_steps=10),
                                   TrainConfig(remat="none")))
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vis_embed"] = jnp.ones((B, 8, cfg.vis_dim), jnp.bfloat16) * 0.1
    params2, opt2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert float(metrics["loss"]) > 0
    # every learnable tensor received a (possibly tiny) update
    moved = [
        float(np.max(np.abs(np.asarray(a, np.float32)
                            - np.asarray(b, np.float32))))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(params2))]
    assert max(moved) > 1e-6   # step-1 lr is tiny under warmup
    assert all(np.isfinite(m) for m in moved)


@pytest.mark.parametrize("arch", ARCH_PARAMS)
def test_arch_smoke_decode(key, arch):
    cfg = get_smoke_config(arch)
    params = transformer.init_model(key, cfg)
    B = 2
    cache = transformer.init_decode_cache(cfg, B, 64)
    kw = _vis_kw(cfg, B)
    toks = jnp.zeros((B, 1), jnp.int32)
    logits, cache2 = transformer.decode_step(params, cfg, cache,
                                             jnp.asarray(3), tokens=toks,
                                             **kw)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not np.isnan(np.asarray(logits, np.float32)).any()


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["qwen3-0.6b", "mamba2-1.3b", "hymba-1.5b",
                                  "musicgen-medium"])
def test_decode_matches_forward(key, arch):
    """Teacher-forced decode logits ≡ full forward logits (cache-exactness).

    Run S tokens through decode one at a time and compare the final-step
    logits against forward() at that position.
    """
    cfg = get_smoke_config(arch)
    params = transformer.init_model(key, cfg)
    B, S = 2, 16   # multiple of the smoke ssd_chunk
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    fwd_logits, _ = transformer.forward(params, cfg, tokens=toks,
                                        remat="none", **_vis_kw(cfg, B))
    cache = transformer.init_decode_cache(cfg, B, 32, kv_dtype=jnp.float32)
    kw = _vis_kw(cfg, B)
    for t in range(S):
        dec_logits, cache = transformer.decode_step(
            params, cfg, cache, jnp.asarray(t), tokens=toks[:, t:t + 1], **kw)
    a = np.asarray(fwd_logits[:, -1], np.float32)
    b = np.asarray(dec_logits[:, 0], np.float32)
    # bf16 activations: compare argmax + correlation rather than bitwise
    assert (a.argmax(-1) == b.argmax(-1)).mean() >= 0.5
    corr = np.corrcoef(a.ravel(), b.ravel())[0, 1]
    assert corr > 0.98


@pytest.mark.slow
def test_unroll_matches_scan(key):
    cfg = get_smoke_config("qwen3-0.6b")
    params = transformer.init_model(key, cfg)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    a, _ = transformer.forward(params, cfg, tokens=toks, remat="none")
    b, _ = transformer.forward(params, cfg, tokens=toks, remat="none",
                               unroll=True)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), atol=1e-2)


def test_last_logits_only(key):
    cfg = get_smoke_config("yi-9b")
    params = transformer.init_model(key, cfg)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size)
    full, _ = transformer.forward(params, cfg, tokens=toks, remat="none")
    last, _ = transformer.forward(params, cfg, tokens=toks, remat="none",
                                  last_logits_only=True)
    assert last.shape == (2, 1, cfg.vocab_size)
    np.testing.assert_allclose(np.asarray(full[:, -1:], np.float32),
                               np.asarray(last, np.float32), atol=1e-2)


# ---------------------------------------------------------------------------
# Attention references
# ---------------------------------------------------------------------------

def _naive_attention(q, k, v, window=0):
    B, S, H, hd = q.shape
    K = k.shape[2]
    G = H // K
    kk = jnp.repeat(k, G, axis=2)
    vv = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bqhd,bthd->bhqt", q, kk) / math.sqrt(hd)
    idx = jnp.arange(S)
    mask = idx[:, None] >= idx[None, :]
    if window > 0:
        mask &= (idx[:, None] - idx[None, :]) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqt,bthd->bqhd", p, vv)


@pytest.mark.parametrize("window", [0, 8])
@pytest.mark.parametrize("S,bq", [
    (32, 16), pytest.param(64, 16, marks=pytest.mark.slow)])
def test_blockwise_attention_matches_naive(key, window, S, bq):
    B, H, K, hd = 2, 4, 2, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    got = attn.blockwise_attention(q, k, v, window=window, block_q=bq,
                                   block_kv=bq)
    want = _naive_attention(q, k, v, window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


def test_dense_attention_matches_naive(key):
    B, S, H, K, hd = 2, 16, 4, 2, 8
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, K, hd))
    v = jax.random.normal(ks[2], (B, S, K, hd))
    idx = jnp.arange(S)
    mask = (idx[:, None] >= idx[None, :])[None, None, None]
    got = attn.dense_attention(q, k, v, mask)
    want = _naive_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# SSD vs naive recurrence
# ---------------------------------------------------------------------------

def test_ssd_scan_matches_naive_recurrence(key):
    """Chunked SSD ≡ the step-by-step linear recurrence."""
    from repro.models.ssm import ssd_scan
    B, L, g, r, P, N, chunk = 2, 32, 1, 4, 8, 16, 8
    ks = jax.random.split(key, 4)
    x = jax.random.normal(ks[0], (B, L, g, r, P)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, L, g, r)))
    a = -jnp.exp(jax.random.normal(ks[2], (g, r)) * 0.3)
    b_in = jax.random.normal(ks[3], (B, L, g, N)) * 0.5
    c_in = jax.random.normal(jax.random.fold_in(key, 7), (B, L, g, N)) * 0.5
    y_ssd, s_ssd = ssd_scan(x, dt, a, b_in, c_in, chunk)

    # naive recurrence
    S = jnp.zeros((B, g, r, N, P))
    ys = []
    for t in range(L):
        decay = jnp.exp(dt[:, t] * a)                       # (B,g,r)
        xb = x[:, t] * dt[:, t][..., None]
        S = S * decay[..., None, None] + jnp.einsum(
            "bgn,bgrp->bgrnp", b_in[:, t], xb)
        ys.append(jnp.einsum("bgn,bgrnp->bgrp", c_in[:, t], S))
    y_naive = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_ssd, np.float32),
                               np.asarray(y_naive), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(s_ssd), np.asarray(S),
                               rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# KV cache int8
# ---------------------------------------------------------------------------

def test_kv_int8_roundtrip_error(key):
    x = jax.random.normal(key, (2, 16, 4, 32), jnp.float32)
    q, s = kvc.quantise_kv(x)
    back = kvc.dequantise_kv(q, s, jnp.float32)
    rel = float(jnp.sqrt(jnp.mean((back - x) ** 2))
                / jnp.sqrt(jnp.mean(x ** 2)))
    assert rel < 0.01


@pytest.mark.slow
def test_int8_decode_close_to_bf16(key):
    cfg = get_smoke_config("yi-9b")
    params = transformer.init_model(key, cfg)
    B, S = 2, 8
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    outs = {}
    for dt in (jnp.bfloat16, jnp.int8):
        cache = transformer.init_decode_cache(cfg, B, 16, kv_dtype=dt)
        for t in range(S):
            logits, cache = transformer.decode_step(
                params, cfg, cache, jnp.asarray(t), tokens=toks[:, t:t + 1])
        outs[str(dt)] = np.asarray(logits, np.float32)
    a, b = outs.values()
    rel = np.sqrt(np.mean((a - b) ** 2)) / np.sqrt(np.mean(a ** 2))
    assert rel < 0.05


# ---------------------------------------------------------------------------
# Config arithmetic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch,target,tol", [
    ("qwen1.5-32b", 32.5e9, 0.15),
    ("yi-9b", 8.8e9, 0.15),
    ("qwen3-0.6b", 0.6e9, 0.4),
    ("qwen2-1.5b", 1.5e9, 0.3),
    ("mamba2-1.3b", 1.3e9, 0.3),
    ("phi3.5-moe-42b-a6.6b", 42e9, 0.15),
])
def test_param_counts_match_published(arch, target, tol):
    n = get_config(arch).param_count()
    assert abs(n - target) / target < tol, f"{arch}: {n / 1e9:.2f}B"


def test_moe_active_params():
    cfg = get_config("phi3.5-moe-42b-a6.6b")
    active = cfg.active_param_count()
    assert abs(active - 6.6e9) / 6.6e9 < 0.3, f"{active / 1e9:.2f}B"


def test_shapes_for_respects_long_context():
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        names = [s.name for s in shapes_for(cfg)]
        if arch in ("mamba2-1.3b", "hymba-1.5b"):
            assert "long_500k" in names
        else:
            assert "long_500k" not in names


@pytest.mark.slow
def test_hybrid_decode_degenerate_layer_mixes(key):
    """Reduced hymba configs with no global (or no SWA) layers decode —
    the extrapolation instrument depends on these (launch/extrapolate)."""
    import dataclasses
    base = get_smoke_config("hymba-1.5b")
    for glb in ((), tuple(range(base.n_layers))):
        cfg = dataclasses.replace(base, global_layers=glb)
        params = transformer.init_model(key, cfg)
        cache = transformer.init_decode_cache(cfg, 2, 32)
        logits, cache2 = transformer.decode_step(
            params, cfg, cache, jnp.asarray(2),
            tokens=jnp.zeros((2, 1), jnp.int32))
        assert logits.shape == (2, 1, cfg.vocab_size)
        assert not np.isnan(np.asarray(logits, np.float32)).any()
