"""Pluggable weight-update backend: fused Pallas path ≡ reference path.

The engine's step-3 datapath is selectable via ``EngineConfig.backend``
(and ``SNNConfig.backend`` at the network level).  These tests pin the
contract every later scaling PR relies on: ``fused_interpret`` (the Pallas
kernel run through the interpreter, i.e. the exact kernel semantics) tracks
``reference`` within float tolerance over long multi-step scans, including
the quantised-weight path and both pairing modes.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.core.engine import (EngineConfig, init_engine,
                               init_engine_population, run_engine,
                               run_engine_population)
from repro.models import snn

T_STEPS = 64


def _run_pair(key, cfg_ref, t_steps=T_STEPS):
    cfg_fused = dataclasses.replace(cfg_ref, backend="fused_interpret")
    state = init_engine(key, cfg_ref)
    train = jax.random.bernoulli(key, 0.35, (t_steps, cfg_ref.n_pre))
    s_ref, post_ref = run_engine(state, train, cfg_ref)
    s_fused, post_fused = run_engine(state, train, cfg_fused)
    return s_ref, post_ref, s_fused, post_fused


@pytest.mark.parametrize("quantise", [False, True])
@pytest.mark.parametrize("n_pre,n_post", [(32, 24), (130, 70)])
def test_fused_matches_reference_over_scan(key, quantise, n_pre, n_post):
    cfg = EngineConfig(n_pre=n_pre, n_post=n_post, eta=0.25,
                       quantise=quantise)
    s_ref, post_ref, s_fused, post_fused = _run_pair(key, cfg)
    np.testing.assert_allclose(np.asarray(s_fused.w), np.asarray(s_ref.w),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(post_fused),
                                  np.asarray(post_ref))


@pytest.mark.parametrize("pairing", ["nearest", "all"])
def test_fused_matches_reference_both_pairings(key, pairing):
    cfg = EngineConfig(n_pre=48, n_post=48, pairing=pairing, eta=0.5)
    s_ref, _, s_fused, _ = _run_pair(key, cfg)
    np.testing.assert_allclose(np.asarray(s_fused.w), np.asarray(s_ref.w),
                               atol=1e-5, rtol=1e-5)


def test_population_backend_equivalence(key):
    """vmapped replicas take the kernel path identically to the loop."""
    cfg = EngineConfig(n_pre=40, n_post=32, quantise=True)
    cfg_fused = dataclasses.replace(cfg, backend="fused_interpret")
    states = init_engine_population(key, cfg, 3)
    trains = jax.random.bernoulli(key, 0.3, (3, T_STEPS, cfg.n_pre))
    s_ref, post_ref = run_engine_population(states, trains, cfg)
    s_fused, post_fused = run_engine_population(states, trains, cfg_fused)
    assert post_ref.shape == (3, T_STEPS, cfg.n_post)
    np.testing.assert_allclose(np.asarray(s_fused.w), np.asarray(s_ref.w),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(post_fused),
                                  np.asarray(post_ref))


def test_population_replicas_are_independent(key):
    """Per-replica keys give distinct initial weights (no broadcast bug)."""
    cfg = EngineConfig(n_pre=16, n_post=16)
    states = init_engine_population(key, cfg, 4)
    assert states.w.shape == (4, 16, 16)
    flat = np.asarray(states.w).reshape(4, -1)
    assert not np.allclose(flat[0], flat[1])


def test_packed_engine_trajectory_bit_identical_to_unpacked(key):
    """Multi-step engine scan: the packed uint8 word datapath (the default
    fused storage format) is bit-identical to the unpacked bitplane kernel
    datapath — array_equal over the full trajectory, both pairings."""
    for pairing in ("nearest", "all"):
        cfg_packed = EngineConfig(n_pre=48, n_post=40, eta=0.25,
                                  pairing=pairing, backend="fused_interpret")
        cfg_unpacked = dataclasses.replace(cfg_packed, packed_history=False)
        assert cfg_packed.packed_history          # packed is the default
        state = init_engine(key, cfg_packed)
        train = jax.random.bernoulli(key, 0.35, (T_STEPS, 48))
        s_p, post_p = run_engine(state, train, cfg_packed)
        s_u, post_u = run_engine(state, train, cfg_unpacked)
        np.testing.assert_array_equal(np.asarray(s_p.w), np.asarray(s_u.w))
        np.testing.assert_array_equal(np.asarray(post_p), np.asarray(post_u))


def test_packed_snn_fc_trajectory_bit_identical_to_unpacked(key):
    """Network-level fc path: packed words ≡ unpacked bitplanes, bit for bit."""
    cfg_packed = snn.mnist_2layer("itp", n_hidden=24,
                                  backend="fused_interpret")
    cfg_unpacked = dataclasses.replace(cfg_packed, packed_history=False)
    batch, t = 4, 10
    state = snn.init_snn(key, cfg_packed, batch)
    raster = jax.random.bernoulli(key, 0.2, (t, batch, 28 * 28))
    s_p, counts_p = snn.run_snn(state, raster, cfg_packed, train=True)
    s_u, counts_u = snn.run_snn(state, raster, cfg_unpacked, train=True)
    np.testing.assert_array_equal(np.asarray(s_p.weights[0]),
                                  np.asarray(s_u.weights[0]))
    np.testing.assert_array_equal(np.asarray(counts_p), np.asarray(counts_u))


def test_depth_beyond_word_width_falls_back_to_unpacked(key):
    """depth > 8 exceeds the packed uint8 word; the fused path must keep
    running on the unpacked bitplane operands (previously-working configs
    stay working) and still match the reference trajectory."""
    cfg = EngineConfig(n_pre=24, n_post=16, depth=9, eta=0.25)
    cfg_fused = dataclasses.replace(cfg, backend="fused_interpret")
    assert cfg_fused.packed_history and not cfg_fused.use_packed_history()
    state = init_engine(key, cfg)
    train = jax.random.bernoulli(key, 0.35, (32, cfg.n_pre))
    s_ref, post_ref = run_engine(state, train, cfg)
    s_fused, post_fused = run_engine(state, train, cfg_fused)
    np.testing.assert_allclose(np.asarray(s_fused.w), np.asarray(s_ref.w),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(post_fused),
                                  np.asarray(post_ref))


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="unknown backend"):
        EngineConfig(backend="cuda")
    with pytest.raises(ValueError, match="unknown backend"):
        snn.mnist_2layer("itp", n_hidden=8, backend="nope")


def test_snn_fc_backend_equivalence(key):
    """Network-level fused fc update ≡ reference einsum update."""
    cfg_ref = snn.mnist_2layer("itp", n_hidden=24)
    cfg_fused = dataclasses.replace(cfg_ref, backend="fused_interpret")
    batch, t = 4, 10
    state = snn.init_snn(key, cfg_ref, batch)
    raster = jax.random.bernoulli(key, 0.2, (t, batch, 28 * 28))
    s_ref, counts_ref = snn.run_snn(state, raster, cfg_ref, train=True)
    s_fused, counts_fused = snn.run_snn(state, raster, cfg_fused, train=True)
    np.testing.assert_allclose(np.asarray(s_fused.weights[0]),
                               np.asarray(s_ref.weights[0]),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(counts_fused),
                                  np.asarray(counts_ref))


def test_launcher_engine_mode_smoke():
    """The launch-path engine workload runs end-to-end on the kernel path."""
    import argparse

    from repro.launch.train import run_engine_training

    args = argparse.Namespace(rule="itp", backend="fused_interpret",
                              engine_pre=32, engine_post=32, replicas=2,
                              steps=8, engine_rate=0.3)
    summary = run_engine_training(args)
    assert summary["rule"] == "itp"
    assert summary["backend"] == "fused_interpret"
    assert summary["sops_per_s"] > 0
    assert np.isfinite(summary["mean_post_rate"])
