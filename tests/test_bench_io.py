"""benchmarks/bench_io.update_bench_json: merge semantics + crash hygiene.

The tracked BENCH_*.json trajectory files are shared by several benchmark
modules; the writer must merge (never clobber siblings), write atomically,
and — the regression here — never leave an *untracked stray matching a
tracked pattern* in the repo root when a run is killed between the temp
write and the rename.
"""
import fnmatch
import json
import os

import pytest

from benchmarks import bench_io
from benchmarks.bench_io import update_bench_json


@pytest.fixture
def bench_root(tmp_path, monkeypatch):
    monkeypatch.setattr(bench_io, "REPO_ROOT", str(tmp_path))
    return tmp_path


def test_merge_keeps_sibling_sections(bench_root):
    update_bench_json("BENCH_x.json", {"engine": {"a": 1}})
    update_bench_json("BENCH_x.json", {"conv": {"b": 2}})
    data = json.loads((bench_root / "BENCH_x.json").read_text())
    assert data == {"engine": {"a": 1}, "conv": {"b": 2}}


def test_write_does_not_narrow_file_mode(bench_root):
    """mkstemp scratch files are born 0600; the rename must not propagate
    that onto the tracked artifact (readable checkout for other users)."""
    path = bench_root / "BENCH_x.json"
    update_bench_json("BENCH_x.json", {"a": 1})
    umask = os.umask(0)
    os.umask(umask)
    assert (path.stat().st_mode & 0o777) == (0o666 & ~umask)
    os.chmod(path, 0o644)
    update_bench_json("BENCH_x.json", {"b": 2})
    assert (path.stat().st_mode & 0o777) == 0o644  # pre-existing mode kept


def test_interrupted_write_leaves_no_stray_file(bench_root):
    """A run killed mid-write (simulated via an unserialisable payload, which
    raises exactly between temp-file creation and os.replace) must leave the
    repo root as it was: no BENCH_*.json.tmp, nothing a `git status` would
    show as untracked under a tracked pattern."""
    update_bench_json("BENCH_x.json", {"engine": {"a": 1}})
    before = sorted(os.listdir(bench_root))
    with pytest.raises(TypeError):
        update_bench_json("BENCH_x.json", {"bad": object()})
    assert sorted(os.listdir(bench_root)) == before
    # the pre-existing trajectory is untouched (atomicity)
    data = json.loads((bench_root / "BENCH_x.json").read_text())
    assert data == {"engine": {"a": 1}}


def test_scratch_name_is_gitignored_pattern():
    """Even if cleanup itself is killed, the scratch name must fall under a
    .gitignore pattern so it can never appear as an untracked stray."""
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    gitignore = open(os.path.join(repo_root, ".gitignore")).read().splitlines()
    patterns = [p.strip() for p in gitignore if p.strip() and not p.startswith("#")]
    sample = bench_io._TMP_PREFIX + "abc123" + bench_io._TMP_SUFFIX
    assert any(fnmatch.fnmatch(sample, pat) for pat in patterns)
