"""Shared fixtures.  Tests run on the single CPU device — the 512-device
override lives ONLY in repro.launch.dryrun (never set globally here)."""
import jax
import pytest

# the lint-fixture tree holds deliberate violations (including a direct
# `import hypothesis`); it is linted via --root by test_analysis.py, never
# collected as tests
collect_ignore = ["fixtures"]


@pytest.fixture
def key():
    return jax.random.PRNGKey(0)


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: long-running integration test")
    config.addinivalue_line(
        "markers",
        "multidevice: forced-8-device subprocess test (see ROADMAP)")
