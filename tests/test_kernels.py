"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.history import init_history, push
from repro.core.lif import LIFParams, LIFState, lif_init, lif_step
from repro.core.stdp import STDPParams, po2_weights, synapse_update


# ---------------------------------------------------------------------------
# ITP-STDP fused kernel
# ---------------------------------------------------------------------------

def _random_setup(key, n_pre, n_post, depth):
    ks = jax.random.split(key, 5)
    w = jax.random.uniform(ks[0], (n_pre, n_post))
    pre_s = jax.random.bernoulli(ks[1], 0.4, (n_pre,)).astype(jnp.float32)
    post_s = jax.random.bernoulli(ks[2], 0.4, (n_post,)).astype(jnp.float32)
    pre_h = jax.random.bernoulli(ks[3], 0.3, (depth, n_pre)).astype(jnp.float32)
    post_h = jax.random.bernoulli(ks[4], 0.3, (depth, n_post)).astype(jnp.float32)
    return w, pre_s, post_s, pre_h, post_h


@pytest.mark.parametrize("n_pre,n_post", [
    (128, 128),
    pytest.param(256, 128, marks=pytest.mark.slow),
    pytest.param(512, 384, marks=pytest.mark.slow),
])
@pytest.mark.parametrize("nearest", [True, False])
@pytest.mark.parametrize("depth", [7, 8])
def test_itp_stdp_kernel_vs_ref(key, n_pre, n_post, nearest, depth):
    from repro.kernels.itp_stdp.kernel import itp_stdp_update
    from repro.kernels.itp_stdp.ref import itp_stdp_update_ref
    w, pre_s, post_s, pre_h, post_h = _random_setup(key, n_pre, n_post, depth)
    p = STDPParams()
    ltp = p.a_plus * po2_weights(depth, p.tau_plus)
    ltd = p.a_minus * po2_weights(depth, p.tau_minus)
    got = itp_stdp_update(w, pre_s, post_s, pre_h, post_h, ltp, ltd,
                          nearest=nearest, eta=0.25, tile_pre=128,
                          tile_post=128, interpret=True)
    want = itp_stdp_update_ref(w, pre_s, post_s, pre_h, post_h, ltp, ltd,
                               nearest=nearest, eta=0.25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-6, atol=1e-6)


def test_engine_weight_update_matches_core(key):
    """Kernel wrapper ≡ repro.core.stdp.synapse_update on ragged sizes."""
    from repro.kernels.itp_stdp.ops import engine_weight_update
    n_pre, n_post, depth = 100, 50, 7
    p = STDPParams()
    w = jax.random.uniform(key, (n_pre, n_post))
    pre_s = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (n_pre,))
    post_s = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5, (n_post,))
    pre_hist = init_history(n_pre, depth)
    post_hist = init_history(n_post, depth)
    for t in range(10):
        pre_hist = push(pre_hist, jax.random.bernoulli(
            jax.random.fold_in(key, 10 + t), 0.3, (n_pre,)).astype(jnp.uint8))
        post_hist = push(post_hist, jax.random.bernoulli(
            jax.random.fold_in(key, 50 + t), 0.3, (n_post,)).astype(jnp.uint8))
    for pairing in ("nearest", "all"):
        got = engine_weight_update(w, pre_s, post_s, pre_hist, post_hist, p,
                                   pairing=pairing, eta=0.5, use_kernel=True,
                                   interpret=True)
        from repro.core.history import as_register
        want = synapse_update(w, pre_s, post_s, as_register(pre_hist),
                              as_register(post_hist), p, pairing=pairing,
                              eta=0.5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# Packed uint8 history datapath (the storage format the fused path runs on)
# ---------------------------------------------------------------------------

def _rolled_histories(key, n_pre, n_post, depth, steps=11):
    pre_h = init_history(n_pre, depth)
    post_h = init_history(n_post, depth)
    for t in range(steps):
        pre_h = push(pre_h, jax.random.bernoulli(
            jax.random.fold_in(key, 10 + t), 0.3, (n_pre,)).astype(jnp.uint8))
        post_h = push(post_h, jax.random.bernoulli(
            jax.random.fold_in(key, 50 + t), 0.3, (n_post,)).astype(jnp.uint8))
    return pre_h, post_h


@pytest.mark.parametrize("pairing", ["nearest", "all"])
@pytest.mark.parametrize("depth", [7, 8])
def test_packed_kernel_bit_identical_to_unpacked(key, depth, pairing):
    """The packed-word kernel is *bit-identical* (array_equal, not allclose)
    to the bitplane kernel: the in-register shift+mask unpack reproduces the
    exact operands, and both route through the same fused body."""
    from repro.core.history import pack_words, registers_depth_major
    from repro.kernels.itp_stdp.ops import (weight_update_depth_major,
                                            weight_update_packed)
    n_pre, n_post = 100, 50
    pre_h, post_h = _rolled_histories(key, n_pre, n_post, depth)
    p = STDPParams()
    w = jax.random.uniform(key, (n_pre, n_post))
    pre_s = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (n_pre,))
    post_s = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5, (n_post,))
    unpacked = weight_update_depth_major(
        w, pre_s, post_s, registers_depth_major(pre_h),
        registers_depth_major(post_h), p, pairing=pairing, eta=0.5,
        interpret=True)
    packed = weight_update_packed(
        w, pre_s, post_s, pack_words(pre_h), pack_words(post_h), p,
        depth=depth, pairing=pairing, eta=0.5, interpret=True)
    np.testing.assert_array_equal(np.asarray(packed), np.asarray(unpacked))
    # the packed reference (unpack + jnp oracle) agrees too
    ref = weight_update_packed(
        w, pre_s, post_s, pack_words(pre_h), pack_words(post_h), p,
        depth=depth, pairing=pairing, eta=0.5, use_kernel=False)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(unpacked),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("depth", [7, 8])
def test_packed_kernel_reads_fixed_point_place_values(key, depth):
    """fixed_point_value is the packed kernel's place-value oracle: with the
    raw po2 read (A=1, τ=1, uncompensated ⇒ read vector 2^-k), all-to-all
    pairing, and only the post side firing, every synapse row i receives
    exactly the binary-fraction value of neuron i's packed word (eq. 2)."""
    from repro.core.history import fixed_point_value, pack_words
    from repro.kernels.itp_stdp.kernel import itp_stdp_update_packed
    from repro.core.stdp import po2_weights
    n = 128
    pre_h, post_h = _rolled_histories(key, n, n, depth)
    words = pack_words(pre_h)
    po2 = po2_weights(depth, 1.0, compensate=False)      # exactly 2^-k
    out = itp_stdp_update_packed(
        jnp.zeros((n, n), jnp.float32),
        jnp.zeros((n,)), jnp.ones((n,)),                 # post fired alone
        words, pack_words(post_h), po2, po2,
        depth=depth, nearest=False, eta=1.0,
        w_min=float("-inf"), w_max=float("inf"),
        tile_pre=128, tile_post=128, interpret=True)
    want = np.asarray(fixed_point_value(words, depth))   # (n,)
    np.testing.assert_allclose(np.asarray(out),
                               np.broadcast_to(want[:, None], (n, n)),
                               rtol=1e-6, atol=1e-6)


def test_engine_weight_update_packed_toggle_matches(key):
    """engine_weight_update(packed=True) ≡ packed=False ≡ core oracle."""
    from repro.core.history import as_register
    from repro.kernels.itp_stdp.ops import engine_weight_update
    n_pre, n_post, depth = 100, 50, 7
    pre_h, post_h = _rolled_histories(key, n_pre, n_post, depth)
    p = STDPParams()
    w = jax.random.uniform(key, (n_pre, n_post))
    pre_s = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.5, (n_pre,))
    post_s = jax.random.bernoulli(jax.random.fold_in(key, 2), 0.5, (n_post,))
    got_packed = engine_weight_update(w, pre_s, post_s, pre_h, post_h, p,
                                      eta=0.5, packed=True, interpret=True)
    got_unpacked = engine_weight_update(w, pre_s, post_s, pre_h, post_h, p,
                                        eta=0.5, packed=False, interpret=True)
    np.testing.assert_array_equal(np.asarray(got_packed),
                                  np.asarray(got_unpacked))
    want = synapse_update(w, pre_s, post_s, as_register(pre_h),
                          as_register(post_h), p, eta=0.5)
    np.testing.assert_allclose(np.asarray(got_packed), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


def test_interpret_default_derives_from_host():
    """The ops wrappers' interpret default comes from the dispatch layer:
    on CPU it resolves to the interpreter (the only thing that runs), on an
    accelerator it must resolve to the compiled kernel — selecting the
    fused path can never silently mean interpreter mode on real hardware."""
    from repro.kernels.dispatch import (default_fused_backend,
                                        default_interpret, resolve_backend)
    assert default_interpret() == resolve_backend(default_fused_backend())[1]
    if jax.default_backend() == "cpu":
        assert default_fused_backend() == "fused_interpret"
        assert default_interpret() is True
    else:  # pragma: no cover - accelerator hosts only
        assert default_fused_backend() == "fused"
        assert default_interpret() is False


def test_ops_wrappers_run_with_derived_interpret_default(key):
    """Omitting ``interpret`` is safe on this host (derived, not hardcoded)."""
    from repro.core.history import pack_words
    from repro.kernels.itp_stdp.ops import weight_update_packed
    n = 16
    pre_h, post_h = _rolled_histories(key, n, n, 7, steps=3)
    out = weight_update_packed(
        jnp.full((n, n), 0.5), jnp.ones((n,)), jnp.zeros((n,)),
        pack_words(pre_h), pack_words(post_h), STDPParams(), depth=7)
    assert out.shape == (n, n)


# ---------------------------------------------------------------------------
# LIF kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,n", [
    (1, 128), (3, 100),
    pytest.param(8, 512, marks=pytest.mark.slow),
    pytest.param(16, 1024, marks=pytest.mark.slow),
])
def test_lif_kernel_vs_ref(key, b, n):
    from repro.kernels.lif.ops import lif_step_kernel
    p = LIFParams(tau=2.0, v_th=0.7)
    v = jax.random.uniform(key, (b, n), minval=-0.5, maxval=1.2)
    i_in = jax.random.uniform(jax.random.fold_in(key, 1), (b, n),
                              maxval=0.8)
    st = LIFState(v=v)
    s1, sp1 = lif_step_kernel(st, i_in, p, use_kernel=True, interpret=True)
    s2, sp2 = lif_step(st, i_in, p)
    np.testing.assert_allclose(np.asarray(s1.v), np.asarray(s2.v),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(sp1), np.asarray(sp2))


def test_lif_kernel_1d_api(key):
    from repro.kernels.lif.ops import lif_step_kernel
    p = LIFParams()
    st = lif_init((40,), p)
    i_in = jax.random.uniform(key, (40,))
    s1, sp1 = lif_step_kernel(st, i_in, p, use_kernel=True, interpret=True)
    assert s1.v.shape == (40,) and sp1.shape == (40,)


# ---------------------------------------------------------------------------
# po2 quantiser kernel
# ---------------------------------------------------------------------------

@settings(max_examples=100, deadline=None)
@given(x=st.floats(-1e6, 1e6, allow_nan=False, width=32))
def test_po2_roundtrip_properties(x):
    from repro.kernels.po2_quant.ref import po2_roundtrip_ref
    q = float(po2_roundtrip_ref(jnp.asarray(x, jnp.float32)))
    if x == 0.0 or abs(x) < 1.2e-38:   # zero / f32-subnormal underflow → 0
        assert q == 0.0 or np.sign(q) == np.sign(x)
    else:
        assert np.sign(q) == np.sign(x)
        if 1e-15 < abs(x) < 1e15:   # in exponent range
            # nearest po2 in log space: ratio within [2^-0.5, 2^0.5]
            ratio = q / x
            assert 0.7071 / 1.001 <= ratio <= 1.4143 * 1.001
            # q is an exact power of two
            m, e = np.frexp(abs(q))
            assert m == 0.5


@pytest.mark.parametrize("n", [
    128, 500, pytest.param(4096, marks=pytest.mark.slow)])
def test_po2_kernel_vs_ref(key, n):
    from repro.kernels.po2_quant.kernel import po2_decode, po2_encode
    from repro.kernels.po2_quant.ref import po2_decode_ref, po2_encode_ref
    x = jax.random.normal(key, (n,)) * jnp.exp(
        jax.random.uniform(jax.random.fold_in(key, 1), (n,), minval=-20,
                           maxval=20))
    pad = (-n) % 128
    xp = jnp.pad(x, (0, pad))
    enc_k = po2_encode(xp, tile=128, interpret=True)[:n]
    enc_r = po2_encode_ref(x)
    np.testing.assert_array_equal(np.asarray(enc_k), np.asarray(enc_r))
    dec_k = po2_decode(jnp.pad(enc_r, (0, pad)), tile=128,
                       interpret=True)[:n]
    dec_r = po2_decode_ref(enc_r)
    np.testing.assert_allclose(np.asarray(dec_k), np.asarray(dec_r))


def test_po2_quantize_tree(key):
    from repro.kernels.po2_quant.ops import po2_quantize_tree
    tree = {"a": jax.random.normal(key, (37,)),
            "b": {"c": jax.random.normal(jax.random.fold_in(key, 1), (8, 9))}}
    out = po2_quantize_tree(tree)
    for leaf in jax.tree_util.tree_leaves(out):
        vals = np.abs(np.asarray(leaf))
        nz = vals[vals > 0]
        m, _ = np.frexp(nz)
        assert (m == 0.5).all()


def test_po2_quantize_kernel_path(key):
    from repro.kernels.po2_quant.ops import po2_quantize
    x = jax.random.normal(key, (77,))
    a = po2_quantize(x, use_kernel=True, interpret=True)
    b = po2_quantize(x, use_kernel=False)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b))
