"""Synthetic data generators + the spike-encoding pipeline."""
import jax.numpy as jnp
import numpy as np

from repro.data import (LMBatchSpec, Prefetcher, encode_batch, host_shard,
                        lm_batches, spike_stream, synthetic_digits,
                        synthetic_fashion, synthetic_fault, zipf_tokens)


def test_digits_shapes_and_range(key):
    x, y = synthetic_digits(key, 32)
    assert x.shape == (32, 28, 28)
    assert float(x.min()) >= 0.0 and float(x.max()) <= 1.0
    assert set(np.asarray(y)) <= set(range(10))


def test_digits_class_structure(key):
    """Same-class images correlate more than cross-class ones."""
    x, y = synthetic_digits(key, 200, noise=0.05, jitter=0)
    x = np.asarray(x).reshape(200, -1)
    y = np.asarray(y)
    same, diff = [], []
    for c in range(10):
        m = x[y == c]
        if len(m) >= 2:
            same.append(np.corrcoef(m[0], m[1])[0, 1])
    for c in range(5):
        a, b = x[y == c], x[y == (c + 5) % 10]
        if len(a) and len(b):
            diff.append(np.corrcoef(a[0], b[0])[0, 1])
    assert np.mean(same) > np.mean(diff) + 0.2


def test_fashion_and_fault_shapes(key):
    x, y = synthetic_fashion(key, 8)
    assert x.shape == (8, 28, 28)
    x, y = synthetic_fault(key, 8, length=256, channels=2)
    assert x.shape == (8, 256, 2)
    assert set(np.asarray(y)) <= set(range(4))


def test_fault_classes_differ_spectrally(key):
    x, y = synthetic_fault(key, 400, noise=0.02)
    x, y = np.asarray(x), np.asarray(y)
    # class 3 (bearing impulses) has the heaviest kurtosis
    def kurt(v):
        v = v - v.mean()
        return (v ** 4).mean() / (v ** 2).mean() ** 2
    k3 = np.mean([kurt(x[i, :, 0]) for i in np.where(y == 3)[0][:20]])
    k0 = np.mean([kurt(x[i, :, 0]) for i in np.where(y == 0)[0][:20]])
    assert k3 > k0


def test_zipf_tokens(key):
    t = zipf_tokens(key, 4, 512, vocab=1000)
    assert t.shape == (4, 512)
    assert int(t.min()) >= 0 and int(t.max()) < 1000
    # zipf: low ids much more frequent
    flat = np.asarray(t).ravel()
    assert (flat < 10).mean() > (flat >= 500).mean()


def test_lm_batches_labels_shifted(key):
    spec = LMBatchSpec(batch=2, seq=16, vocab=100)
    b = next(lm_batches(key, spec))
    np.testing.assert_array_equal(np.asarray(b["labels"][:, :-1]),
                                  np.asarray(b["tokens"][:, 1:]))
    assert (np.asarray(b["labels"][:, -1]) == -1).all()


def test_host_shard():
    batch = {"tokens": jnp.arange(8)[:, None]}
    s0 = host_shard(batch, 0, 2)
    s1 = host_shard(batch, 1, 2)
    np.testing.assert_array_equal(np.asarray(s0["tokens"]).ravel(),
                                  [0, 1, 2, 3])
    np.testing.assert_array_equal(np.asarray(s1["tokens"]).ravel(),
                                  [4, 5, 6, 7])


def test_encode_batch_rate(key):
    x = jnp.stack([jnp.zeros((10,)), jnp.linspace(0, 1, 10)])
    s = encode_batch(key, x, 800)
    assert s.shape == (800, 2, 10)
    # max-value element fires ≈ every step, zero never
    rates = np.asarray(s.mean(axis=0))
    assert rates[1, -1] > 0.95
    assert rates[1, 0] < 0.05


def test_spike_stream(key):
    it = spike_stream(key, lambda k, n: synthetic_digits(k, n),
                      batch=4, t_steps=6, n_steps=3)
    batches = list(it)
    assert len(batches) == 3
    assert batches[0]["spikes"].shape == (6, 4, 784)
    assert batches[0]["labels"].shape == (4,)


def test_prefetcher_preserves_order():
    it = iter([{"i": i} for i in range(20)])
    pf = Prefetcher(it, depth=3)
    out = [int(b["i"]) for b in pf]
    assert out == list(range(20))


def test_prefetcher_matches_unprefetched_spike_stream(key):
    """Prefetching is a pure latency optimisation: same batches, same order."""
    sampler = lambda k, n: synthetic_digits(k, n)  # noqa: E731
    plain = list(spike_stream(key, sampler, batch=4, t_steps=6, n_steps=5))
    with Prefetcher(spike_stream(key, sampler, batch=4, t_steps=6,
                                 n_steps=5)) as pf:
        fetched = list(pf)
    assert len(fetched) == len(plain)
    for a, b in zip(plain, fetched):
        np.testing.assert_array_equal(np.asarray(a["spikes"]),
                                      np.asarray(b["spikes"]))
        np.testing.assert_array_equal(np.asarray(a["labels"]),
                                      np.asarray(b["labels"]))


def test_prefetcher_close_shuts_down_cleanly():
    """Early abandonment must stop the fill thread, not leak it."""
    def slow_source():
        for i in range(1000):
            yield {"i": i}

    pf = Prefetcher(slow_source(), depth=2)
    first = next(pf)
    assert int(first["i"]) == 0
    pf.close()
    assert not pf._thread.is_alive()
    # idempotent, and a closed prefetcher raises StopIteration not a hang
    pf.close()
    assert list(pf) == []
