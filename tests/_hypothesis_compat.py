"""Hermetic stand-in for ``hypothesis`` (property-based testing).

The test-suite uses a small slice of the hypothesis API (``@given`` with
keyword strategies, ``@settings``, ``st.floats/integers/lists/data``).  When
the real package is installed we re-export it unchanged; otherwise a minimal
deterministic fallback runs each property over a fixed set of examples
(range edges first, then seeded-pseudorandom draws) so the suite collects
and runs with no network and no extra dependencies.

The fallback is intentionally simple: it does no shrinking, no example
database, and caps the number of examples regardless of
``settings(max_examples=...)`` — it is a smoke-level property check, not a
replacement for real hypothesis runs in CI.
"""
from __future__ import annotations

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _FALLBACK_EXAMPLES = 8

    class _Strategy:
        """A value source: fixed edge examples first, then seeded draws."""

        def __init__(self, draw, edges=()):
            self._draw = draw
            self.edges = tuple(edges)

        def sample(self, rng, index=None):
            if index is not None and index < len(self.edges):
                return self.edges[index]
            return self._draw(rng)

    class _DataStrategy:
        """Marker for ``st.data()``; materialised per-example as ``_Data``."""

    class _Data:
        def __init__(self, rng):
            self._rng = rng

        def draw(self, strategy, label=None):
            return strategy.sample(self._rng)

    class _Strategies:
        @staticmethod
        def floats(min_value=-1e9, max_value=1e9, *, allow_nan=True,
                   allow_infinity=None, width=64):
            edges = [min_value, max_value, (min_value + max_value) / 2.0]
            if min_value <= 0.0 <= max_value:
                edges.append(0.0)
            return _Strategy(
                lambda rng: rng.uniform(min_value, max_value), edges)

        @staticmethod
        def integers(min_value, max_value):
            edges = [min_value, max_value, (min_value + max_value) // 2]
            return _Strategy(
                lambda rng: rng.randint(min_value, max_value), edges)

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5, (False, True))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda rng: rng.choice(elements), elements[:2])

        @staticmethod
        def lists(elements, *, min_size=0, max_size=None):
            hi = max_size if max_size is not None else min_size + 8

            def draw(rng):
                size = rng.randint(min_size, hi)
                return [elements.sample(rng) for _ in range(size)]

            return _Strategy(draw)

        @staticmethod
        def data():
            return _DataStrategy()

    st = _Strategies()

    def settings(max_examples=None, deadline=None, **_ignored):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        def deco(fn):
            sig = inspect.signature(fn)
            passthrough = [p for name, p in sig.parameters.items()
                           if name not in strategies]

            def wrapper(*args, **kwargs):
                limit = getattr(wrapper, "_compat_max_examples", None)
                n = min(limit or _FALLBACK_EXAMPLES, _FALLBACK_EXAMPLES)
                for i in range(n):
                    rng = random.Random(f"{fn.__module__}.{fn.__name__}:{i}")
                    drawn = {}
                    for name, strat in strategies.items():
                        if isinstance(strat, _DataStrategy):
                            drawn[name] = _Data(rng)
                        else:
                            drawn[name] = strat.sample(rng, i)
                    fn(*args, **kwargs, **drawn)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            # pytest reads the signature to resolve fixtures: expose only the
            # parameters *not* supplied by strategies
            wrapper.__signature__ = sig.replace(parameters=passthrough)
            return wrapper

        return deco
