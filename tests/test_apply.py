"""plasticity.apply dispatch layer: a third-party rule rides every backend.

The slim-protocol contract (ISSUE 9): a rule defined *outside* the repo —
just a state machine (``init_state``/``step``), a readout, and a magnitude
map, registered through :class:`repro.plasticity.Rank1Rule` — runs
end-to-end on every backend it declares (reference, fused_interpret,
sparse, and across the sharded engine) with zero edits to the engine or
model files, and the backends it does *not* declare fail at config
construction with the registry's pinned messages — never mid-trace.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import EngineConfig, init_engine, run_engine
from repro.plasticity import Rank1Rule, register_rule
from repro.plasticity.base import RULES

THIRD_PARTY_BACKENDS = ("reference", "fused_interpret", "sparse")


@dataclasses.dataclass(frozen=True)
class DecayTraceRule(Rank1Rule):
    """Minimal third-party-style rule: a per-neuron decaying uint8 trace.

    Each spike injects 64 into the trace; every step halves it (a shift,
    saturating at 127 so the uint8 word never wraps).  The update
    magnitude is just ``amplitude * trace / 128`` — nothing the built-in
    rules share, so every backend it reaches is reached purely through
    the ``Rank1Rule`` adapters.
    """

    name: str = "thirdparty_trace"

    def init_state(self, n, depth):
        return jnp.zeros((n,), jnp.uint8)

    def step(self, state, spikes, *, depth):
        fired = jnp.asarray(spikes).astype(jnp.uint8)
        return jnp.minimum((state >> 1) + fired * jnp.uint8(64), jnp.uint8(127))

    def readout(self, state):
        return state[None, :]

    def magnitudes_from_readout(self, arr, amplitude, tau, *, depth,
                                pairing="nearest", compensate=True):
        return amplitude * arr[0].astype(jnp.float32) / 128.0

    def last_spikes(self, state):
        return (state >= jnp.uint8(64)).astype(jnp.float32)


@dataclasses.dataclass(frozen=True)
class DenseOnlyRule(DecayTraceRule):
    """Same trace, but declaring the reference datapath only."""

    name: str = "thirdparty_dense"
    has_kernel: bool = False
    has_sparse: bool = False


@pytest.fixture
def third_party_rules():
    full = register_rule(DecayTraceRule())
    dense = register_rule(DenseOnlyRule())
    yield full, dense
    RULES.pop(full.name, None)
    RULES.pop(dense.name, None)


def _run(key, backend, **kw):
    cfg = EngineConfig(n_pre=16, n_post=8, eta=0.25,
                       rule="thirdparty_trace", backend=backend, **kw)
    state = init_engine(key, cfg)
    train = jax.random.bernoulli(key, 0.4, (24, cfg.n_pre))
    final, post = run_engine(state, train, cfg)
    return state, final, post


@pytest.mark.parametrize("backend", THIRD_PARTY_BACKENDS)
def test_third_party_rule_runs_on_declared_backends(key, backend,
                                                    third_party_rules):
    state0, final, post = _run(key, backend)
    w = np.asarray(final.w)
    assert np.isfinite(w).all()
    assert (w >= 0.0).all() and (w <= 1.0).all()
    # the trace actually drives learning — weights move off the init
    assert not np.array_equal(w, np.asarray(state0.w))
    assert final.pre_hist.dtype == jnp.uint8


@pytest.mark.parametrize("backend", ("fused_interpret", "sparse"))
def test_third_party_backends_match_reference(key, backend,
                                              third_party_rules):
    _, ref, post_ref = _run(key, "reference")
    _, got, post_got = _run(key, backend)
    np.testing.assert_allclose(np.asarray(got.w), np.asarray(ref.w),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(post_got), np.asarray(post_ref))


def test_third_party_rule_crosses_sharded_engine(key, third_party_rules):
    from repro.core.engine_sharded import (make_sharded_engine_step,
                                           shard_engine_state)

    cfg = EngineConfig(n_pre=16, n_post=8, eta=0.25,
                       rule="thirdparty_trace", backend="fused_interpret")
    state0 = init_engine(key, cfg)
    train = jax.random.bernoulli(key, 0.4, (16, cfg.n_pre))
    ref_state, ref_post = run_engine(state0, train, cfg)

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    with mesh:
        st = shard_engine_state(init_engine(key, cfg), mesh)
        step = make_sharded_engine_step(cfg, mesh)
        posts = []
        for t in range(train.shape[0]):
            st, post = step(st, train[t])
            posts.append(np.asarray(post))
    np.testing.assert_allclose(np.asarray(ref_state.w), np.asarray(st.w),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_array_equal(np.asarray(ref_post), np.stack(posts))


def test_undeclared_backends_fail_at_config_construction(third_party_rules):
    # config-construction errors with the registry's pinned messages —
    # not trace errors from deep inside a backend
    with pytest.raises(ValueError, match="no fused kernel"):
        EngineConfig(rule="thirdparty_dense", backend="fused_interpret")
    with pytest.raises(ValueError, match="no fused kernel"):
        EngineConfig(rule="thirdparty_dense", backend="fused")
    with pytest.raises(ValueError, match="no event-driven"):
        EngineConfig(rule="thirdparty_dense", backend="sparse")


def test_dense_only_rule_runs_on_reference(key, third_party_rules):
    cfg = EngineConfig(n_pre=12, n_post=6, eta=0.25,
                       rule="thirdparty_dense", backend="reference")
    state = init_engine(key, cfg)
    train = jax.random.bernoulli(key, 0.4, (12, cfg.n_pre))
    final, _ = run_engine(state, train, cfg)
    assert np.isfinite(np.asarray(final.w)).all()
