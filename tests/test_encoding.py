"""Rate coding + ISI analysis (§IV-B)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoding import (isi_histogram, isi_histogram_batched,
                                 minmax_normalise, rate_code,
                                 select_history_depth)


def test_minmax_range(key):
    x = jax.random.normal(key, (4, 100)) * 7 + 3
    n = minmax_normalise(x, axis=-1)
    assert float(n.min()) >= 0.0 and float(n.max()) <= 1.0
    np.testing.assert_allclose(np.asarray(n.min(axis=-1)), 0.0, atol=1e-6)
    np.testing.assert_allclose(np.asarray(n.max(axis=-1)), 1.0, atol=1e-6)


def test_rate_code_expectation(key):
    """Eq. 30: empirical rate → x_norm."""
    x = jnp.asarray([0.0, 0.25, 0.5, 0.75, 1.0])
    s = rate_code(key, x, 4000)
    rate = np.asarray(s.mean(axis=0))
    np.testing.assert_allclose(rate, np.asarray(x), atol=0.03)


def test_isi_histogram_agrees_with_batched(key):
    s = jax.random.bernoulli(key, 0.3, (200, 8)).astype(jnp.uint8)
    a = isi_histogram(s)
    b = isi_histogram_batched(s)
    np.testing.assert_array_equal(a.counts, b.counts)
    assert a.n_intervals == b.n_intervals


def test_isi_geometric_distribution(key):
    """Bernoulli(p) spikes → ISI ~ Geometric(p); depth-7 coverage matches
    1-(1-p)^7 — the §IV-B mechanism behind the paper's depth choice."""
    p = 0.4
    s = jax.random.bernoulli(key, p, (5000, 16)).astype(jnp.uint8)
    stats = isi_histogram_batched(s)
    want = 1 - (1 - p) ** 7
    assert abs(stats.coverage(7) - want) < 0.01


def test_depth_selection(key):
    s = jax.random.bernoulli(key, 0.5, (10_000, 32)).astype(jnp.uint8)
    stats = isi_histogram_batched(s)
    d = select_history_depth(stats, 0.99)
    # Geometric(0.5): 1-(0.5)^d ≥ 0.99 → d = 7 (coverage 0.9922)
    assert d == 7


def test_empty_raster():
    s = jnp.zeros((50, 4), jnp.uint8)
    stats = isi_histogram_batched(s)
    assert stats.n_intervals == 0
    assert stats.coverage(7) == 0.0
