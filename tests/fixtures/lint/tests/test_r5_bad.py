"""Fixture: R5 violation — direct hypothesis import in a test module."""
from hypothesis import given, strategies as st


@given(st.integers())
def test_identity(x):
    assert x == x
