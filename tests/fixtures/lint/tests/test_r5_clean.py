"""Fixture: R5 clean twin — goes through the compat shim."""
from _hypothesis_compat import given, st


@given(st.integers())
def test_identity(x):
    assert x == x
