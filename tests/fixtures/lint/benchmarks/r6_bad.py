"""Fixture: R6 violation — raw write of a tracked BENCH_ artifact."""
import json


def save(data):
    with open("BENCH_fixture.json", "w") as f:
        json.dump(data, f)
