"""Fixture: R6 clean twin — merged read-modify-write via bench_io."""
import json

from benchmarks.bench_io import update_bench_json


def save(data, out_path):
    update_bench_json("BENCH_fixture.json", {"fixture": data})
    with open(out_path, "w") as f:      # per-run out-dir file: allowed
        json.dump(data, f)
