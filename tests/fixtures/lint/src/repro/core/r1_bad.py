"""Fixture: R1 violation — raw shard_map outside the compat shim."""
import jax


def pod_mean(f, mesh, spec):
    return jax.shard_map(f, mesh=mesh, in_specs=spec, out_specs=spec)
