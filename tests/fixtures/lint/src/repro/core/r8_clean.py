"""R8 clean twin: dispatches through the plasticity apply layer.

Mentioning the hook names (kernel_readout, fused_update_from_readout) in
a docstring — or defining a method with a hook name — must not fire; only
call sites do.
"""


class FakeRule:
    def kernel_readout(self, state, *, packed):
        return state


def good_update(plan, w, pre, post, pre_state, post_state):
    return plan.update(w, pre, post, pre_state, post_state)
