"""Fixture: R1 clean twin — routes through the version shim."""
from repro.distributed.sharding import shard_map_compat


def pod_mean(f, mesh, spec):
    return shard_map_compat(f, mesh=mesh, in_specs=spec, out_specs=spec)
