"""R8 violation: calls a rule datapath hook outside repro/plasticity/."""


def bad_update(rule, state, packed):
    return rule.kernel_readout(state, packed=packed)
