"""Fixture: R4 violation — data-dependent one-arg jnp.where."""
import jax.numpy as jnp


def event_indices(spikes):
    (idx,) = jnp.where(spikes != 0)
    return idx
