"""Fixture: R2 clean twin — the sanctioned dispatch re-export."""
from repro.kernels.dispatch import spike_events


def events(spikes, cap):
    return spike_events(spikes, cap)
