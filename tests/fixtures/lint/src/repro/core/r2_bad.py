"""Fixture: R2 violation — direct kernel-package import from core."""
from repro.kernels.itp_sparse.events import spike_events


def events(spikes, cap):
    return spike_events(spikes, cap)
