"""Fixture: R4 clean twin — static-size event extraction."""
import jax.numpy as jnp


def event_indices(spikes, cap):
    n = spikes.shape[0]
    (idx,) = jnp.where(spikes != 0, size=cap, fill_value=n)
    sel = jnp.where(idx < n, idx, n)           # 3-arg select: static shape
    return sel
