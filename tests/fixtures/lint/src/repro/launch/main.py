"""Fixture entry point: everything imported here is R7-reachable."""
from repro.core import (r1_bad, r1_clean, r2_bad, r2_clean, r4_bad, r4_clean,
                        r8_bad, r8_clean)
from repro.kernels.fake import ops
from repro.used_mod import used

__all__ = ["r1_bad", "r1_clean", "r2_bad", "r2_clean", "r4_bad", "r4_clean",
           "r8_bad", "r8_clean", "ops", "used"]
