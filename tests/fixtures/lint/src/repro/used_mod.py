"""Fixture: R7 clean twin — reachable from the launch entry point."""


def used():
    return 7
