"""Fixture: R3 — one literal interpret default (bad) + the None form."""
from repro.kernels.dispatch import default_interpret


def fake_op_bad(x, *, interpret: bool = True):
    return x if interpret else -x


def fake_op_clean(x, *, interpret=None):
    if interpret is None:
        interpret = default_interpret()
    return x if interpret else -x
