"""Fixture: R7 orphan — no entry point imports this module."""


def unused():
    return 42
