"""Property tests for the static-shape spike-event extraction.

The event list is the load-bearing primitive of the sparse backend: its
shape must be jit-stable at ANY spike density, its ordering must be
deterministic (first-``cap`` active indices, ascending), and saturation
beyond ``max_events`` must drop exactly the highest-indexed events.
Pinned here against a plain ``np.nonzero`` oracle over random rasters
and over packed uint8 history words across depths 1..8.
"""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.core.history import pack_bitplanes
from repro.kernels.itp_sparse.events import event_cap, spike_events, word_events


def _oracle(spikes: np.ndarray, cap: int) -> tuple[np.ndarray, int]:
    """First-``cap`` active indices ascending, sentinel-padded to ``cap``."""
    (active,) = np.nonzero(spikes)
    kept = active[:cap]
    idx = np.full((cap,), spikes.shape[-1], dtype=np.int32)
    idx[: len(kept)] = kept
    return idx, len(kept)


@settings(max_examples=60, deadline=None)
@given(data=st.data(), n=st.integers(1, 40), cap=st.integers(1, 45))
def test_spike_events_matches_nonzero_prefix(data, n, cap):
    spikes = np.asarray(data.draw(st.lists(st.integers(0, 1), min_size=n, max_size=n)))
    idx, count = spike_events(jnp.asarray(spikes), cap)
    want_idx, want_count = _oracle(spikes, event_cap(n, cap))
    assert idx.shape == (event_cap(n, cap),)  # static at any density
    assert idx.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(idx), want_idx)
    assert int(count) == want_count


@settings(max_examples=40, deadline=None)
@given(data=st.data(), n=st.integers(1, 32))
def test_spike_events_saturates_at_cap(data, n):
    """All-ones input: the cap keeps the lowest indices, count saturates."""
    cap = data.draw(st.integers(1, n))
    idx, count = spike_events(jnp.ones((n,)), cap)
    np.testing.assert_array_equal(np.asarray(idx), np.arange(cap))
    assert int(count) == cap


def test_spike_events_shapes_are_density_invariant():
    """Same jitted extraction serves silent, sparse, and dense inputs."""
    n, cap = 16, 5
    fn = jax.jit(lambda s: spike_events(s, cap))
    shapes = set()
    for raster in (np.zeros(n), np.eye(n)[3], np.ones(n)):
        idx, count = fn(jnp.asarray(raster))
        shapes.add((idx.shape, str(idx.dtype)))
    assert shapes == {((cap,), "int32")}
    idx, count = fn(jnp.zeros((n,)))
    assert int(count) == 0 and np.all(np.asarray(idx) == n)  # all sentinel


def test_event_cap_validation():
    assert event_cap(10, None) == 10
    assert event_cap(10, 99) == 10  # clamped to population
    assert event_cap(10, 3) == 3
    np.testing.assert_raises(ValueError, event_cap, 10, 0)
    np.testing.assert_raises(ValueError, event_cap, 10, -1)


@settings(max_examples=60, deadline=None)
@given(data=st.data(), depth=st.integers(1, 8), n=st.integers(1, 24))
def test_word_events_reads_packed_slots(data, depth, n):
    """Packed-word extraction ≡ extraction on the unpacked bit slot."""
    row = st.lists(st.integers(0, 1), min_size=n, max_size=n)
    bits = np.asarray(data.draw(st.lists(row, min_size=depth, max_size=depth)))  # (depth, n)
    words = pack_bitplanes(jnp.asarray(bits))  # (n,) uint8
    slot = data.draw(st.integers(0, depth - 1))
    cap = data.draw(st.integers(1, n + 2))
    idx, count = word_events(words, depth, cap, slot=slot)
    want_idx, want_count = _oracle(bits[slot], event_cap(n, cap))
    np.testing.assert_array_equal(np.asarray(idx), want_idx)
    assert int(count) == want_count


def test_word_events_slot_validation():
    words = jnp.zeros((4,), jnp.uint8)
    np.testing.assert_raises(ValueError, word_events, words, 4, None, slot=4)
    np.testing.assert_raises(ValueError, word_events, words, 4, None, slot=-1)
    np.testing.assert_raises(ValueError, word_events, words, 9)
