"""Train-to-accuracy subsystem: homeostasis + WTA competition, the
label-assignment evaluator, the epoch loop, the shared CLI builders, and
the EngineConfig/SNNConfig validator parity pin."""

import argparse

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.engine import EngineConfig
from repro.data import encode_batch, synthetic_digits
from repro.launch import cli
from repro.models import snn
from repro.train.stdp_trainer import (
    TrainerConfig,
    assign_labels,
    assignment_accuracy,
    assignment_predict,
    train_to_accuracy,
)


# ---------------------------------------------------------------------------
# Homeostasis + hard WTA (network-level competition dynamics)
# ---------------------------------------------------------------------------


def _digit_spikes(key, batch, t_steps):
    k_data, k_enc = jax.random.split(key)
    x, _ = synthetic_digits(k_data, batch)
    return encode_batch(k_enc, x, t_steps)


def test_homeostasis_raises_thresholds_of_active_neurons(key):
    cfg = snn.mnist_2layer("itp", n_hidden=32, theta_plus=0.1, theta_tau=50.0)
    state = snn.init_snn(key, cfg, 8)
    spikes = _digit_spikes(key, 8, 20)
    state, counts = snn.run_snn(state, spikes, cfg, train=True)
    theta = np.asarray(state.layers[0].theta)
    totals = np.asarray(counts).sum(axis=0)
    assert theta.shape == (32,)
    assert theta.max() > 0.0, "no threshold moved despite spiking"
    # a neuron that never fired accrues no homeostatic penalty …
    np.testing.assert_allclose(theta[totals == 0.0], 0.0)
    # … and the most active neuron carries a strictly positive one
    assert theta[totals.argmax()] > 0.0


def test_homeostasis_frozen_in_eval_and_survives_reset(key):
    cfg = snn.mnist_2layer("itp", n_hidden=32, theta_plus=0.1, theta_tau=50.0)
    state = snn.init_snn(key, cfg, 8)
    spikes = _digit_spikes(key, 8, 20)
    state, _ = snn.run_snn(state, spikes, cfg, train=True)
    theta = np.asarray(state.layers[0].theta)
    # θ is the slow homeostatic variable: reset_dynamics clears membranes
    # and histories but must carry θ across sample boundaries …
    state = snn.reset_dynamics(state, cfg, 8)
    np.testing.assert_array_equal(np.asarray(state.layers[0].theta), theta)
    # … and a frozen (train=False) pass must not move it
    state, _ = snn.run_snn(state, spikes, cfg, train=False)
    np.testing.assert_array_equal(np.asarray(state.layers[0].theta), theta)


def test_homeostasis_disabled_keeps_theta_zero(key):
    cfg = snn.mnist_2layer("itp", n_hidden=32)
    assert cfg.theta_plus == 0.0
    state = snn.init_snn(key, cfg, 4)
    state, _ = snn.run_snn(state, _digit_spikes(key, 4, 15), cfg, train=True)
    np.testing.assert_allclose(np.asarray(state.layers[0].theta), 0.0)


def test_hard_wta_caps_spikes_per_sample_per_step(key):
    wta = snn.mnist_2layer("itp", n_hidden=32, hard_wta=True)
    soft = snn.mnist_2layer("itp", n_hidden=32)
    spikes = _digit_spikes(key, 8, 25)
    st_wta = snn.init_snn(key, wta, 8)
    st_soft = snn.init_snn(key, soft, 8)
    _, counts_wta = snn.run_snn(st_wta, spikes, wta, train=False)
    _, counts_soft = snn.run_snn(st_soft, spikes, soft, train=False)
    # at most one winner per sample and step → per-sample total ≤ t_steps
    per_sample = np.asarray(counts_wta).sum(axis=1)
    assert per_sample.max() <= 25
    # WTA is strictly a restriction of the soft-inhibition dynamics
    assert np.asarray(counts_wta).sum() <= np.asarray(counts_soft).sum()


# ---------------------------------------------------------------------------
# Label-assignment evaluator
# ---------------------------------------------------------------------------


def test_assign_labels_recovers_class_selective_neurons():
    labels = jnp.array([0, 1, 2, 0, 1, 2])
    # counts[n, f] = 5 if sample n's label == neuron f's preferred class
    counts = 5.0 * (labels[:, None] == (jnp.arange(6)[None, :] % 3))
    assignments = assign_labels(counts, labels, 3)
    np.testing.assert_array_equal(np.asarray(assignments), np.arange(6) % 3)
    pred = assignment_predict(counts, assignments, 3)
    np.testing.assert_array_equal(np.asarray(pred), np.asarray(labels))
    assert assignment_accuracy(counts, labels, assignments, 3) == 1.0


def test_assignment_vote_is_population_mean_not_sum():
    # class 0 owns 3 neurons, class 1 owns 1; a sample driving the class-1
    # neuron harder must win despite class 0's larger population
    assignments = jnp.array([0, 0, 0, 1], jnp.int32)
    counts = jnp.array([[1.0, 1.0, 1.0, 4.0]])
    pred = assignment_predict(counts, assignments, 2)
    assert int(pred[0]) == 1


def test_silent_neurons_carry_no_vote():
    labels = jnp.array([0, 1])
    counts = jnp.array([[3.0, 0.0], [0.0, 0.0]])  # neuron 1 never fires
    assignments = assign_labels(counts, labels, 2)
    assert int(assignments[0]) == 0
    # neuron 1 falls to class 0 by argmax-of-zeros; its zero counts add
    # nothing to either class's mean vote for a firing sample
    pred = assignment_predict(jnp.array([[5.0, 0.0]]), assignments, 2)
    assert int(pred[0]) == 0


# ---------------------------------------------------------------------------
# The epoch loop end-to-end
# ---------------------------------------------------------------------------


def test_train_to_accuracy_beats_chance():
    sampler, n_classes = cli.sampler_for("2layer-snn")
    cfg = snn.mnist_2layer("itp", theta_plus=0.05, hard_wta=True)
    tcfg = TrainerConfig(
        epochs=1,
        batches_per_epoch=6,
        batch=16,
        t_steps=30,
        assign_batches=4,
        eval_batches=4,
    )
    r = train_to_accuracy(cfg, sampler, n_classes, tcfg)
    assert len(r["accuracy_curve"]) == tcfg.epochs
    assert r["final_accuracy"] == r["accuracy_curve"][-1]
    assert r["chance"] == pytest.approx(0.1)
    assert r["final_accuracy"] >= 2 * r["chance"], r["accuracy_curve"]
    assert r["sim_steps"] == 6 * 30
    assert isinstance(r["state"], snn.SNNState)


def test_trainer_config_validates_counts():
    with pytest.raises(ValueError, match="epochs"):
        TrainerConfig(epochs=0)
    with pytest.raises(ValueError, match="eval_batches"):
        TrainerConfig(eval_batches=0)


# ---------------------------------------------------------------------------
# Shared CLI builders (examples/train_snn.py ≡ repro.launch.train --snn)
# ---------------------------------------------------------------------------


def _example_parser():
    ap = argparse.ArgumentParser()
    cli.add_net_flag(ap, "--net")
    cli.add_update_flags(ap)
    cli.add_train_flags(ap)
    return ap


def _launcher_parser():
    ap = argparse.ArgumentParser()
    cli.add_net_flag(ap, "--snn", default=None)
    cli.add_update_flags(ap)
    cli.add_train_flags(ap, batch_default=8)
    return ap


def test_both_entry_points_build_identical_configs():
    flags = "--rule exact --epochs 2 --batch 4 --theta-plus 0.1 --hard-wta"
    argv = ["2layer-snn"] + flags.split() + ["--hidden", "32"]
    a = _example_parser().parse_args(["--net"] + argv)
    b = _launcher_parser().parse_args(["--snn"] + argv)
    assert cli.net_from_args(a) == cli.net_from_args(b) == "2layer-snn"
    assert cli.snn_config_from_args(a) == cli.snn_config_from_args(b)
    assert cli.trainer_config_from_args(a) == cli.trainer_config_from_args(b)
    cfg = cli.snn_config_from_args(a)
    assert cfg.rule == "exact" and cfg.hard_wta and cfg.theta_plus == 0.1
    tcfg = cli.trainer_config_from_args(a)
    assert tcfg.epochs == 2 and tcfg.batch == 4


def test_unset_flags_defer_to_maker_defaults():
    args = _example_parser().parse_args(["--net", "2layer-snn"])
    cfg = cli.snn_config_from_args(args)
    # mnist_2layer's own soft inhibition survives when --inhibition unset
    assert cfg == snn.mnist_2layer("itp")
    assert cli.trainer_config_from_args(args) == TrainerConfig()


def test_legacy_steps_namespace_maps_to_one_epoch():
    args = argparse.Namespace(snn="2layer-snn", batch=8, steps=60, engine_rate=0.3)
    assert cli.net_from_args(args) == "2layer-snn"
    tcfg = cli.trainer_config_from_args(args)
    assert tcfg.epochs == 1
    assert tcfg.t_steps == 30 and tcfg.batches_per_epoch == 2
    assert tcfg.batch == 8
    cfg = cli.snn_config_from_args(args)
    assert cfg.rule == "itp" and cfg.backend == "reference"


def test_samplers_cover_every_paper_network():
    assert set(cli.SAMPLERS) == set(snn.PAPER_NETWORKS)
    for net in cli.SAMPLERS:
        sampler, n_classes = cli.sampler_for(net)
        x, y = sampler(jax.random.PRNGKey(0), 3)
        assert x.shape[0] == 3 and y.shape == (3,)
        assert n_classes >= 2


def test_launcher_snn_mode_reports_accuracy():
    from repro.launch.train import run_snn_training

    args = argparse.Namespace(
        net="2layer-snn",
        rule="itp",
        backend="reference",
        hidden=32,
        epochs=1,
        batches_per_epoch=2,
        batch=4,
        t_raster=10,
        assign_batches=2,
        eval_batches=2,
        theta_plus=0.05,
        hard_wta=True,
    )
    summary = run_snn_training(args)
    assert summary["net"] == "2layer-snn"
    assert summary["epochs"] == 1 and summary["steps"] == 2 * 10
    assert summary["sops_per_s"] > 0
    assert len(summary["accuracy_curve"]) == 1
    assert 0.0 <= summary["final_accuracy"] <= 1.0


# ---------------------------------------------------------------------------
# Validator parity: EngineConfig and SNNConfig share one message surface
# ---------------------------------------------------------------------------


def _raises_message(fn):
    with pytest.raises(ValueError) as exc:
        fn()
    return str(exc.value)


@pytest.mark.parametrize(
    "kw",
    [
        {"rule": "hebbian"},
        {"rule": "exact", "backend": "sparse"},
        {"backend": "sparse", "max_events": 0},
        {"pairing": "both"},
    ],
)
def test_engine_and_snn_configs_raise_identical_messages(kw):
    rule = kw.pop("rule", "itp")
    m_engine = _raises_message(lambda: EngineConfig(rule=rule, **kw))
    m_snn = _raises_message(lambda: snn.mnist_2layer(rule, **kw))
    assert m_engine == m_snn
