"""Benchmark: ISI-based spike-history depth selection — paper Fig. 6.

Rate-codes samples from the three (synthetic stand-in) datasets, builds
the pooled ISI histogram/CDF, and reports depth-7 coverage (paper: 99.53 %
over 97.6 M spikes; ≥ 99 % is the design criterion)."""
from __future__ import annotations

import json
import os

import jax
import numpy as np

from repro.core.encoding import (ISIStats, isi_histogram_batched,
                                 select_history_depth)
from repro.data import (encode_batch, synthetic_digits, synthetic_fashion,
                        synthetic_fault)

PAPER = {"depth": 7, "coverage_at_7": 0.9953}


def run(out_dir: str = "experiments/bench", verbose: bool = True,
        n_samples: int = 256, t_steps: int = 64) -> dict:
    key = jax.random.PRNGKey(0)
    datasets = {
        "digits": lambda k: synthetic_digits(k, n_samples)[0],
        "fashion": lambda k: synthetic_fashion(k, n_samples)[0],
        "fault": lambda k: synthetic_fault(k, n_samples, length=512)[0],
    }
    counts = np.zeros(65, np.int64)
    n_spikes = 0
    per_ds = {}
    for i, (name, gen) in enumerate(datasets.items()):
        k1, k2 = jax.random.split(jax.random.fold_in(key, i))
        x = gen(k1)
        spikes = encode_batch(k2, x, t_steps)          # (T, B, N)
        T, B, N = spikes.shape
        flat = spikes.reshape(T, B * N)
        stats = isi_histogram_batched(flat)
        counts += stats.counts
        n_spikes += stats.n_spikes
        per_ds[name] = {"coverage_at_7": stats.coverage(7),
                        "n_spikes": stats.n_spikes}

    cdf = np.cumsum(counts) / max(counts.sum(), 1)
    pooled = ISIStats(counts=counts, cdf=cdf, n_spikes=n_spikes,
                      n_intervals=int(counts.sum()))
    depth = select_history_depth(pooled, 0.99)
    result = {
        "pooled_coverage_at_7": pooled.coverage(7),
        "selected_depth": depth,
        "n_spikes": n_spikes,
        "per_dataset": per_ds,
        "histogram": counts[:16].tolist(),
        "cdf": cdf[:16].tolist(),
        "paper": PAPER,
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "isi.json"), "w") as f:
        json.dump(result, f)
    if verbose:
        print("— ISI depth selection (paper §IV-B / Fig. 6) —")
        print(f"  pooled coverage at depth 7: {pooled.coverage(7):.4f} "
              f"(paper 0.9953, criterion ≥ 0.99)")
        print(f"  selected depth            : {depth} (paper 7)")
        for name, d in per_ds.items():
            print(f"    {name:8s}: coverage@7 {d['coverage_at_7']:.4f} "
                  f"({d['n_spikes']} spikes)")
        print("  (image stand-ins reach ≥0.986; the sinusoidal fault "
              "stand-in has arcsine-distributed intensities — longer ISIs "
              "than the paper's preprocessed motor data; method and the "
              "image-data conclusion reproduce, see EXPERIMENTS.md)")
    return result


if __name__ == "__main__":
    run()
