"""Benchmark: online-plasticity serving cost — latency, throughput, memory.

The serving layer's value proposition is the paper's 1-byte register made
operational: a user's continual-learning state (the "plasticity cache") is
the rule's packed uint8 word planes, so thousands of per-user networks
stay resident per GiB and every request is one vmapped engine scan.  This
module prices that claim:

  * ``latency``    — p50/p99 wall-clock of a full-batch ``serve_step``
    (compile excluded; host scatter/gather included, since that is the
    per-request cost a deployment pays).
  * ``throughput`` — requests/s and simulation-steps/s vs ``max_batch``:
    the lanes are independent, so throughput should scale with the batch
    until the host dispatch floor dominates.
  * ``memory``     — per rule: plasticity-cache bytes/session, the
    bytes/neuron CI gates at ≤ 2 (history word + eligibility word), and
    sessions/GiB both for the cache alone and for the full resident state.
  * ``isolation``  — the determinism contract, re-checked in the
    benchmark harness: a session served interleaved with strangers is
    bit-identical (spikes + weights + words) to the same session served
    solo.  CI gates this boolean.

Writes the tracked repo-root BENCH_serve.json via ``bench_io`` (quick
runs land in the gitignored ``.quick`` twin).
"""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np

from benchmarks.bench_io import update_bench_json
from repro.core.engine import EngineConfig
from repro.serve import Request, ServeConfig, SessionStore, serve_step

RULES = ("itp", "exact", "mstdp")
BATCH_SIZES = (1, 2, 4, 8, 16)
QUICK_BATCH_SIZES = (1, 4)


def _load(cfg: EngineConfig, scfg: ServeConfig, n_requests: int,
          sessions: int, seed: int = 0, rate: float = 0.3) -> list[Request]:
    key = jax.random.PRNGKey(seed)
    reqs = []
    for i in range(n_requests):
        sub = jax.random.fold_in(key, i)
        raster = (jax.random.uniform(sub, (scfg.t_steps, cfg.n_pre)) < rate)
        reqs.append(Request(sid=f"user{i % sessions}",
                            raster=np.asarray(raster, np.float32)))
    return reqs


def _serve_batches(store: SessionStore, reqs: list[Request],
                   scfg: ServeConfig) -> list[float]:
    """Serve ``reqs`` in full batches of distinct sessions; per-batch seconds."""
    times = []
    b = scfg.max_batch
    for i in range(0, len(reqs) - b + 1, b):
        t0 = time.perf_counter()
        serve_step(store, reqs[i:i + b], scfg)
        times.append(time.perf_counter() - t0)
    return times


def measure_latency(cfg: EngineConfig, scfg: ServeConfig, reps: int) -> dict:
    """p50/p99 full-batch serve_step wall-clock (first batch = warmup)."""
    store = SessionStore(cfg)
    reqs = _load(cfg, scfg, (reps + 1) * scfg.max_batch, scfg.max_batch)
    times = _serve_batches(store, reqs, scfg)[1:]   # drop the compile batch
    return {
        "reps": len(times),
        "p50_ms": float(np.percentile(times, 50) * 1e3),
        "p99_ms": float(np.percentile(times, 99) * 1e3),
        "mean_ms": float(np.mean(times) * 1e3),
    }


def measure_throughput(cfg: EngineConfig, t_steps: int, batch_sizes,
                       reps: int) -> list[dict]:
    """Requests/s and sim-steps/s as the lane count grows."""
    rows = []
    for b in batch_sizes:
        scfg = ServeConfig(max_batch=b, t_steps=t_steps)
        store = SessionStore(cfg)
        reqs = _load(cfg, scfg, (reps + 1) * b, b)
        times = _serve_batches(store, reqs, scfg)[1:]
        total = sum(times)
        rows.append({
            "max_batch": b,
            "requests_per_s": reps * b / total,
            "sim_steps_per_s": reps * b * t_steps / total,
        })
    return rows


def measure_memory(n_pre: int, n_post: int) -> list[dict]:
    """The per-rule session-memory table the storage claim lives in."""
    rows = []
    for rule in RULES:
        store = SessionStore(EngineConfig(n_pre=n_pre, n_post=n_post,
                                          rule=rule))
        per = store.state_bytes_per_session()
        rows.append({
            "rule": rule,
            "bytes_per_session": per,
            "bytes_per_neuron": per / (n_pre + n_post),
            "sessions_per_gb": store.sessions_per_gb(),
            "resident_bytes_per_session": store.resident_bytes_per_session(),
            "resident_sessions_per_gb": store.sessions_per_gb(resident=True),
        })
    return rows


def check_isolation(cfg: EngineConfig, scfg: ServeConfig) -> bool:
    """Interleaved-vs-solo bit-identity (the contract CI gates)."""
    reqs = _load(cfg, scfg, 2 * scfg.max_batch, 2 * scfg.max_batch, seed=7)
    probe = reqs[0].sid

    inter = SessionStore(cfg)
    a = serve_step(inter, reqs[:scfg.max_batch], scfg)[0]
    b = serve_step(inter, [Request(probe, reqs[scfg.max_batch].raster)],
                   scfg)[0]

    solo = SessionStore(cfg)
    c = serve_step(solo, [reqs[0]], scfg)[0]
    d = serve_step(solo, [Request(probe, reqs[scfg.max_batch].raster)],
                   scfg)[0]

    same = (np.array_equal(a.post, c.post) and np.array_equal(b.post, d.post)
            and np.array_equal(np.asarray(inter.peek(probe).w),
                               np.asarray(solo.peek(probe).w)))
    for x, y in zip(inter.peek(probe).pre_words + inter.peek(probe).post_words,
                    solo.peek(probe).pre_words + solo.peek(probe).post_words):
        same = same and np.array_equal(np.asarray(x), np.asarray(y))
    return bool(same)


def run(out_dir: str = "experiments/bench", verbose: bool = True,
        n_pre: int = 256, n_post: int = 64, t_steps: int = 32,
        max_batch: int = 8, reps: int = 30, batch_sizes=BATCH_SIZES,
        rule: str = "itp", quick: bool = False) -> dict:
    cfg = EngineConfig(n_pre=n_pre, n_post=n_post, rule=rule)
    scfg = ServeConfig(max_batch=max_batch, t_steps=t_steps)

    latency = measure_latency(cfg, scfg, reps)
    throughput = measure_throughput(cfg, t_steps, batch_sizes, reps)
    memory = measure_memory(n_pre, n_post)
    isolated = check_isolation(cfg, ServeConfig(max_batch=min(max_batch, 4),
                                                t_steps=min(t_steps, 8)))

    out = {
        "benchmark": "online_plasticity_serving_cost",
        "quick": quick,
        "rule": rule,
        "n_pre": n_pre,
        "n_post": n_post,
        "t_steps": t_steps,
        "max_batch": max_batch,
        "latency": latency,
        "throughput": throughput,
        "memory": memory,
        "isolation": {"interleaved_bit_identical": isolated},
        "note": "latency includes host scatter/gather; compile excluded",
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "serve_cost.json"), "w") as f:
        json.dump(out, f)
    bench_name = "BENCH_serve.quick.json" if quick else "BENCH_serve.json"
    update_bench_json(bench_name, {"serving": out})
    if verbose:
        print(f"— serving cost (rule={rule}, {n_pre}x{n_post}, "
              f"T={t_steps}, batch={max_batch}) —")
        print(f"  step latency: p50 {latency['p50_ms']:.2f} ms, "
              f"p99 {latency['p99_ms']:.2f} ms over {latency['reps']} reps")
        print(f"  {'batch':>6s} {'req/s':>10s} {'steps/s':>12s}")
        for r in throughput:
            print(f"  {r['max_batch']:6d} {r['requests_per_s']:10.1f} "
                  f"{r['sim_steps_per_s']:12.1f}")
        for m in memory:
            print(f"  {m['rule']:>6s}: {m['bytes_per_session']} B/session "
                  f"({m['bytes_per_neuron']:.0f} B/neuron, "
                  f"{m['sessions_per_gb']:.2e} sessions/GiB cache, "
                  f"{m['resident_sessions_per_gb']:.2e} resident)")
        print(f"  interleaved bit-identical: {isolated}")
        print(f"  → {bench_name} (serving section)")
    return out


if __name__ == "__main__":
    run()
