"""Benchmark: mean-field drift model — paper Fig. 5 + the §IV-A numbers.

Reproduces, from the Table I parameterisation:
  * update-curve RMSE (exact vs uncompensated ITP)  — paper: 9.4753 %
  * compensated RMSE                                 — paper: 0 (exact)
  * equilibrium-point shift                          — paper: 24.69 %
  * convergence-time error                           — paper: 7.36 %
plus the Fig. 5 panel data (LTP/LTD curves, local drift, trajectories),
written to experiments/bench/drift.json.
"""
from __future__ import annotations

import json
import os

import jax.numpy as jnp
import numpy as np

from repro.core.drift import (DriftParams, drift_analytic, iterate,
                              make_rule, paper_metrics)

PAPER = {"update_curve_rmse": 0.094753,
         "equilibrium_rel_err": 0.2469,
         "convergence_time_rel_err": 0.0736}


def run(out_dir: str = "experiments/bench", verbose: bool = True) -> dict:
    p = DriftParams()
    metrics = paper_metrics(p)

    # Fig. 5 panel data
    x = np.linspace(-20, 20, 801)
    w_grid = np.linspace(0.0, 1.0, 201)
    panels = {
        "x": x.tolist(),
        "curve_exact": np.asarray(make_rule("exact", p)(jnp.asarray(x))).tolist(),
        "curve_itp": np.asarray(make_rule("itp", p)(jnp.asarray(x))).tolist(),
        "curve_itp_nocomp": np.asarray(
            make_rule("itp_nocomp", p)(jnp.asarray(x))).tolist(),
        "w": w_grid.tolist(),
        "drift_exact": np.asarray(
            drift_analytic(jnp.asarray(w_grid, jnp.float32), "exact", p)).tolist(),
        "drift_itp_nocomp": np.asarray(
            drift_analytic(jnp.asarray(w_grid, jnp.float32), "itp_nocomp",
                           p)).tolist(),
    }
    w0 = jnp.asarray(np.linspace(0.1, 0.6, 6), jnp.float32)
    panels["traj_exact"] = np.asarray(iterate(w0, "exact", p, 400)).tolist()
    panels["traj_itp_nocomp"] = np.asarray(
        iterate(w0, "itp_nocomp", p, 400)).tolist()

    result = {"metrics": metrics, "paper": PAPER,
              "match": {
                  "rmse_abs_err": abs(metrics["update_curve_rmse"]
                                      - PAPER["update_curve_rmse"]),
                  "comp_rmse_is_zero": metrics[
                      "update_curve_rmse_compensated"] < 1e-6,
              }}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "drift.json"), "w") as f:
        json.dump({**result, "fig5_panels": panels}, f)
    if verbose:
        m = metrics
        print("— drift (paper §IV-A / Fig. 5) —")
        print(f"  update-curve RMSE   : {m['update_curve_rmse']:.6f}  "
              f"(paper 0.094753)")
        print(f"  compensated RMSE    : {m['update_curve_rmse_compensated']:.2e} "
              f" (paper: exactly 0)")
        print(f"  equilibrium shift   : {m['equilibrium_rel_err']:.4f}  "
              f"(paper 0.2469)")
        print(f"  convergence-time err: {m['convergence_time_rel_err']:.4f}  "
              f"(paper 0.0736)")
    return result


if __name__ == "__main__":
    run()
