"""Static jaxpr fingerprint of the rule × backend × layer-kind matrix.

Runs the layer-2 contract audit (``repro.analysis.jaxpr_audit``) and
records the per-cell primitive-count table into the tracked
``BENCH_static.json`` — a host-independent cost fingerprint: unlike the
wall-clock benchmarks, the traced-graph size only moves when the code
(or the jax version) changes, so CI can diff it to catch silent graph
bloat or a cell dropping out of the matrix.  Quick mode writes
``BENCH_static.quick.json`` (same content — the audit is already
CI-cheap; the split keeps artifact handling uniform with the other
benchmarks).
"""
from __future__ import annotations

import json
import os

from benchmarks.bench_io import update_bench_json
from repro.analysis.jaxpr_audit import run_audit


def run(out_dir: str, quick: bool = False, verbose: bool = True) -> dict:
    report = run_audit()
    out = {
        "jax_version": report["jax_version"],
        "n_cells": report["n_cells"],
        "n_violating": report["n_violating"],
        "cells": report["cells"],
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "static_audit.json"), "w") as f:
        json.dump(out, f, indent=1)
    bench_name = "BENCH_static.quick.json" if quick else "BENCH_static.json"
    update_bench_json(bench_name, {"static_audit": out})
    if verbose:
        print(f"— static jaxpr audit ({out['n_cells']} cells, jax {out['jax_version']}) —")
        cols = f"{'rule':>10} {'backend':>16} {'kind':>7} {'eqns':>5} {'uint8':>5} {'viol':>4}"
        print(f"  {cols}")
        for c in out["cells"]:
            row = (
                f"{c['rule']:>10s} {c['backend']:>16s} {c['kind']:>7s} "
                f"{c.get('n_eqns', 0):5d} {str(c.get('has_uint8', False)):>5s} "
                f"{len(c['violations']):4d}"
            )
            print(f"  {row}")
    if report["n_violating"]:
        raise SystemExit(
            f"static audit: {report['n_violating']} cell(s) violate the "
            "dataflow contracts — run `python -m tools.check --audit`"
        )
    return out
