"""Benchmark: event-driven sparse backend — speedup vs spike density.

The repo-side analogue of the paper's event-driven-efficiency argument:
the same engine (identical LIF dynamics, scan loop, and jit) runs the
dense ``reference`` weight-update datapath against the event-driven
``sparse`` datapath over a grid of input spike densities.  The dense
update always touches all n² synapses; the sparse update extracts
static-shape event lists (capped at ``max_events``, scaled to the
density with 2× headroom so drops stay rare) and scatters only the
touched rows/columns — so its cost scales with events, not synapses,
and there is a *crossover density* below which sparse wins.

Two speedup columns per density cell:

  * ``model_speedup``   — the host-independent event-cost model: dense
    touches n² cells per step; sparse touches n·(e_pre + e_post) cells
    plus O(n) per side for the event extraction, with e the static
    event-list cap actually in effect.  This is the structural claim and
    is what CI gates unconditionally.
  * ``measured_speedup`` — jitted engine-scan wall-clock (SOP/s ratio).
    Gated only where it is meaningful — on a compiled fused backend host
    (``gate_measured``) — because small-n CPU wall-clock is dominated by
    dispatch overhead, not the datapath (same caveat as the rule-cost
    grid, see ROADMAP).

The crossover densities (modelled and measured, linear interpolation of
the speedup-vs-density curve through 1.0) land in the JSON next to the
roofline arithmetic-intensity ridge (``benchmarks/roofline.py``) — the
target the sparse datapath's gather/scatter traffic is priced against.

Merges a ``sparse`` section into the tracked repo-root BENCH_engine.json
(``benchmarks/bench_io.py`` read-modify-write, never clobbering the
engine/rules/conv sections); ``--quick`` runs use the smaller,
incomparable grid and land in the gitignored ``.quick`` twin.
"""

from __future__ import annotations

import json
import math
import os

import jax

from benchmarks.bench_io import update_bench_json
from benchmarks.roofline import HBM_BW, PEAK_FLOPS
from benchmarks.rule_cost import _time_fn
from repro.core.engine import EngineConfig, init_engine, run_engine
from repro.kernels.dispatch import default_fused_backend
from repro.kernels.itp_sparse.events import event_cap

DENSITIES = (0.01, 0.02, 0.05, 0.1, 0.2, 0.4)
QUICK_DENSITIES = (0.02, 0.2)

# event-list cap headroom over the expected per-step event count: 2× the
# Bernoulli mean keeps cap-overflow drops rare while keeping the static
# gather/scatter shapes proportional to the density
CAP_HEADROOM = 2.0


def density_cap(n: int, density: float) -> int:
    """The static event-list cap the sparse backend runs with at ``density``."""
    return event_cap(n, max(1, math.ceil(CAP_HEADROOM * density * n)))


def model_costs(n: int, density: float) -> tuple[float, float]:
    """(dense, sparse) modelled cells-touched per engine step.

    Dense: the full n² synapse matrix.  Sparse: n·e per side (the LTP
    scatter touches n rows × e_post columns, the LTD scatter e_pre rows
    × n columns) plus an O(n) event extraction per side, with e the
    static cap in effect at this density.
    """
    e = density_cap(n, density)
    return float(n * n), float(2 * n * e + 2 * n)


def measure_density_throughput(
    n: int, t_steps: int, density: float, backend: str, seed: int = 0
) -> float:
    """SOP/s of a jitted engine scan at ``density`` on ``backend``."""
    key = jax.random.PRNGKey(seed)
    max_events = density_cap(n, density) if backend == "sparse" else None
    cfg = EngineConfig(n_pre=n, n_post=n, backend=backend, max_events=max_events)
    state = init_engine(key, cfg)
    train = jax.random.bernoulli(key, density, (t_steps, n))
    fn = jax.jit(lambda s, x: run_engine(s, x, cfg))
    return n * n * t_steps / _time_fn(fn, state, train)


def crossover_density(rows: list[dict], key: str) -> float | None:
    """Density where the ``key`` speedup curve crosses 1.0 (sparse = dense).

    Linear interpolation between adjacent grid points; the lowest-density
    crossing wins.  None when the curve never crosses (all-above means
    sparse wins everywhere benchmarked; all-below, nowhere).
    """
    pts = [(r["density"], r[key]) for r in rows if r.get(key) is not None]
    for (d0, s0), (d1, s1) in zip(pts, pts[1:]):
        if (s0 - 1.0) * (s1 - 1.0) <= 0.0 and s0 != s1:
            return d0 + (d1 - d0) * (1.0 - s0) / (s1 - s0)
    return None


def measure_density_grid(n: int, t_steps: int, densities) -> list[dict]:
    """Sparse-vs-dense engine throughput + event-cost model per density."""
    rows = []
    for density in densities:
        dense_cost, sparse_cost = model_costs(n, density)
        dense = measure_density_throughput(n, t_steps, density, "reference")
        sparse = measure_density_throughput(n, t_steps, density, "sparse")
        rows.append(
            {
                "density": density,
                "n": n,
                "t_steps": t_steps,
                "max_events": density_cap(n, density),
                "dense_sops_per_s": dense,
                "sparse_sops_per_s": sparse,
                "measured_speedup": sparse / dense,
                "model_dense_cost": dense_cost,
                "model_sparse_cost": sparse_cost,
                "model_speedup": dense_cost / sparse_cost,
            }
        )
    return rows


def run(
    out_dir: str = "experiments/bench",
    verbose: bool = True,
    n: int = 256,
    t_steps: int = 50,
    densities=DENSITIES,
    quick: bool = False,
) -> dict:
    grid = measure_density_grid(n, t_steps, densities)
    out = {
        "benchmark": "sparse_vs_dense_engine_throughput",
        "unit": "SOP/s",
        "quick": quick,
        "gate_measured": default_fused_backend() == "fused",
        "n": n,
        "t_steps": t_steps,
        "grid": grid,
        "crossover_density_model": crossover_density(grid, "model_speedup"),
        "crossover_density_measured": crossover_density(grid, "measured_speedup"),
        "ai_ridge_flops_per_byte": PEAK_FLOPS / HBM_BW,
        "note": "event-cost model gated always; wall-clock only on compiled hosts",
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "sparse_cost.json"), "w") as f:
        json.dump(out, f)
    bench_name = "BENCH_engine.quick.json" if quick else "BENCH_engine.json"
    update_bench_json(bench_name, {"sparse": out})
    if verbose:
        print(f"— sparse vs dense engine throughput (n={n}, {t_steps} steps) —")
        hdr = (
            f"  {'density':>8s} {'cap':>5s} {'dense':>12s} {'sparse':>12s}"
            f" {'measured×':>10s} {'model×':>8s}"
        )
        print(hdr)
        for r in grid:
            print(
                f"  {r['density']:8.3f} {r['max_events']:5d}"
                f" {r['dense_sops_per_s']:12.3e} {r['sparse_sops_per_s']:12.3e}"
                f" {r['measured_speedup']:10.2f} {r['model_speedup']:8.2f}"
            )
        xm, xw = out["crossover_density_model"], out["crossover_density_measured"]
        print(
            f"  crossover density: model "
            f"{'—' if xm is None else format(xm, '.3f')}, measured "
            f"{'—' if xw is None else format(xw, '.3f')}"
            f" (AI ridge {out['ai_ridge_flops_per_byte']:.0f} FLOP/byte)"
        )
        print(f"  → {bench_name} (sparse section, {len(grid)} densities)")
    return out


if __name__ == "__main__":
    run()
