"""Benchmark: unsupervised train-to-accuracy — ITP vs exact STDP, per backend.

Runs the full system-level protocol of ``repro.train.stdp_trainer``
(epochs of unsupervised STDP with hard-WTA competition + adaptive-threshold
homeostasis, then label-assignment evaluation) on the 2-layer SNN over the
digits stand-in, for every cell of the accuracy grid:

    itp   × reference, fused_interpret, sparse
    exact × reference, fused_interpret

The claim under test is the paper's end-to-end one: ITP-STDP (po2 updates
with timing compensation, eq. 18) reaches the *same classification
accuracy* as exact STDP — not just the same weight trajectories.  With a
shared seed the compensated-ITP and exact trajectories are bit-identical
on the reference backend (pinned in tests/test_plasticity.py), so the
``itp_vs_exact_gap`` here should be ≈ 0; ``GAP_TOLERANCE`` leaves room for
kernel-backend numeric drift only.

Merges a ``train_to_accuracy`` section into the tracked repo-root
BENCH_accuracy.json (``benchmarks/bench_io.py`` read-modify-write);
``--quick`` runs use a shorter, incomparable protocol and land in the
gitignored ``.quick`` twin, which the CI accuracy gate reads.
"""

from __future__ import annotations

import json
import os
import time

from benchmarks.bench_io import update_bench_json
from repro.launch.cli import sampler_for
from repro.models import snn
from repro.train.stdp_trainer import TrainerConfig, train_to_accuracy

NET = "2layer-snn"

# rule × backend cells; sparse is itp-only (counter rules have no
# event-driven datapath — resolve_rule_backend rejects the pair)
GRID = (
    ("itp", "reference"),
    ("itp", "fused_interpret"),
    ("itp", "sparse"),
    ("exact", "reference"),
    ("exact", "fused_interpret"),
)
QUICK_GRID = (
    ("itp", "reference"),
    ("itp", "sparse"),
    ("exact", "reference"),
)

# |final_itp − final_exact| on the reference backend; ≈ 0 by the
# trajectory-identity pin, tolerance covers eval sampling granularity only
GAP_TOLERANCE = 0.05

# homeostasis / competition knobs that make unsupervised STDP class-
# selective on the digits stand-in (tuned once, shared by every cell so
# differences isolate rule × backend)
THETA_PLUS = 0.05
HARD_WTA = True

FULL_TCFG = TrainerConfig(
    epochs=6,
    batches_per_epoch=8,
    batch=16,
    t_steps=30,
    assign_batches=6,
    eval_batches=8,
)
QUICK_TCFG = TrainerConfig(
    epochs=2,
    batches_per_epoch=8,
    batch=16,
    t_steps=30,
    assign_batches=4,
    eval_batches=4,
)


def run_cell(rule: str, backend: str, tcfg: TrainerConfig) -> dict:
    """One grid cell: train to accuracy, return the JSON-ready record."""
    sampler, n_classes = sampler_for(NET)
    cfg = snn.PAPER_NETWORKS[NET](
        rule,
        backend=backend,
        theta_plus=THETA_PLUS,
        hard_wta=HARD_WTA,
    )
    t0 = time.time()
    r = train_to_accuracy(cfg, sampler, n_classes, tcfg)
    return {
        "rule": rule,
        "backend": backend,
        "accuracy_curve": r["accuracy_curve"],
        "final_accuracy": r["final_accuracy"],
        "best_accuracy": max(r["accuracy_curve"]),
        "mean_eval_rate": r["mean_eval_rates"][-1],
        "train_seconds": r["train_seconds"],
        "wall_seconds": round(time.time() - t0, 3),
        "chance": r["chance"],
    }


def run(
    out_dir: str = "experiments/bench",
    verbose: bool = True,
    quick: bool = False,
) -> dict:
    grid = QUICK_GRID if quick else GRID
    tcfg = QUICK_TCFG if quick else FULL_TCFG
    cells = [run_cell(rule, backend, tcfg) for rule, backend in grid]
    by_cell = {f"{c['rule']}/{c['backend']}": c for c in cells}
    itp_ref = by_cell["itp/reference"]["final_accuracy"]
    exact_ref = by_cell["exact/reference"]["final_accuracy"]
    gap = abs(itp_ref - exact_ref)
    itp_finals = [c["final_accuracy"] for c in cells if c["rule"] == "itp"]
    out = {
        "benchmark": "unsupervised_train_to_accuracy",
        "net": NET,
        "quick": quick,
        "protocol": {
            "epochs": tcfg.epochs,
            "batches_per_epoch": tcfg.batches_per_epoch,
            "batch": tcfg.batch,
            "t_steps": tcfg.t_steps,
            "assign_batches": tcfg.assign_batches,
            "eval_batches": tcfg.eval_batches,
            "seed": tcfg.seed,
            "theta_plus": THETA_PLUS,
            "hard_wta": HARD_WTA,
        },
        "chance": cells[0]["chance"],
        "cells": cells,
        "itp_vs_exact_gap": gap,
        "gap_tolerance": GAP_TOLERANCE,
        "itp_backend_spread": max(itp_finals) - min(itp_finals),
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "accuracy.json"), "w") as f:
        json.dump(out, f)
    bench_name = "BENCH_accuracy.quick.json" if quick else "BENCH_accuracy.json"
    update_bench_json(bench_name, {"train_to_accuracy": out})
    if verbose:
        print(
            f"— unsupervised train-to-accuracy ({NET}, "
            f"{tcfg.epochs} epochs, chance {out['chance']:.2f}) —"
        )
        print(
            f"  {'rule':>6s} {'backend':>16s} {'final':>7s} {'best':>7s} "
            f"{'rate':>7s} {'train s':>8s}"
        )
        for c in cells:
            print(
                f"  {c['rule']:>6s} {c['backend']:>16s} "
                f"{c['final_accuracy']:7.3f} {c['best_accuracy']:7.3f} "
                f"{c['mean_eval_rate']:7.3f} {c['train_seconds']:8.2f}"
            )
        print(
            f"  itp-vs-exact gap (reference): {gap:.3f} "
            f"(tolerance {GAP_TOLERANCE}), itp backend spread "
            f"{out['itp_backend_spread']:.3f}"
        )
    return out


if __name__ == "__main__":
    run()
