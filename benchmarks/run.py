"""Benchmark orchestrator: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (DESIGN.md §7):
    drift             — Fig. 5 + §IV-A numbers (RMSE / equilibrium / conv.)
    isi               — Fig. 6 ISI histogram + depth-7 coverage
    network_accuracy  — Table II accuracy parity (3 nets × 3 rules)
    engine_cost       — Tables III-V op/bit model + measured SOP/s
    rule_cost         — per-rule engine throughput (ITP vs exact & co.)
    conv_cost         — im2col-fused conv update: reference vs Pallas grid
    roofline          — §Roofline terms from the dry-run artifacts

``--only <name>`` runs a single module; ``--quick`` shrinks the
network-accuracy protocol for CI-speed runs.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=("drift", "isi", "network_accuracy",
                                       "engine_cost", "rule_cost",
                                       "conv_cost", "roofline"))
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="experiments/bench")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    summary = {}
    t_start = time.time()

    def want(name):
        return args.only is None or args.only == name

    if want("drift"):
        from benchmarks import drift
        t0 = time.time()
        r = drift.run(args.out)
        summary["drift"] = {"seconds": round(time.time() - t0, 1),
                            "rmse": r["metrics"]["update_curve_rmse"]}
        print()
    if want("isi"):
        from benchmarks import isi
        t0 = time.time()
        r = isi.run(args.out)
        summary["isi"] = {"seconds": round(time.time() - t0, 1),
                          "coverage_at_7": r["pooled_coverage_at_7"]}
        print()
    if want("network_accuracy"):
        from benchmarks import network_accuracy
        t0 = time.time()
        kw = {"n_train": 48, "n_test": 32, "seeds": (0,)} if args.quick else {}
        network_accuracy.run(args.out, **kw)
        summary["network_accuracy"] = {"seconds": round(time.time() - t0, 1)}
        print()
    if want("engine_cost"):
        from benchmarks import engine_cost
        t0 = time.time()
        if args.quick:
            r = engine_cost.run(args.out, sizes=(64, 256),
                                grid_sizes=(64, 128, 256), grid_batches=(1, 4),
                                grid_steps=25, quick=True)
        else:
            r = engine_cost.run(args.out)
        summary["engine_cost"] = {
            "seconds": round(time.time() - t0, 1),
            "speedups": [t["speedup"] for t in r["throughput"]],
            "fused_speedups": [c["fused_speedup"] for c in r["backend_grid"]]}
        print()
    if want("rule_cost"):
        from benchmarks import rule_cost
        t0 = time.time()
        if args.quick:
            r = rule_cost.run(args.out, sizes=(64, 128), t_steps=25,
                              quick=True)
        else:
            r = rule_cost.run(args.out)
        summary["rule_cost"] = {
            "seconds": round(time.time() - t0, 1),
            "itp_vs_exact": [c.get("itp_vs_exact_speedup")
                             for c in r["grid"]]}
        print()
    if want("conv_cost"):
        from benchmarks import conv_cost
        t0 = time.time()
        r = conv_cost.run(args.out, quick=args.quick)
        summary["conv_cost"] = {
            "seconds": round(time.time() - t0, 1),
            "fused_speedups": [c["fused_speedup"] for c in r["grid"]]}
        print()
    if want("roofline"):
        from benchmarks import roofline
        t0 = time.time()
        r = roofline.run(args.out)
        summary["roofline"] = {"seconds": round(time.time() - t0, 1),
                               "cells": len(r["rows"]),
                               "missing": len(r["missing"])}
        print()

    summary["total_seconds"] = round(time.time() - t_start, 1)
    with open(os.path.join(args.out, "summary.json"), "w") as f:
        json.dump(summary, f, indent=1)
    print(f"benchmarks complete in {summary['total_seconds']}s "
          f"→ {args.out}/")


if __name__ == "__main__":
    main()
