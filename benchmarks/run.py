"""Benchmark orchestrator: ``PYTHONPATH=src python -m benchmarks.run``.

One module per paper table/figure (DESIGN.md §7), registered in
``MODULES`` — the single source the ``--only`` choices, the ``--list``
output, and the dispatch loop all derive from, so the CLI surface cannot
drift from what actually runs (CI smokes ``--list`` against the modules
it exercises):

    drift             — Fig. 5 + §IV-A numbers (RMSE / equilibrium / conv.)
    isi               — Fig. 6 ISI histogram + depth-7 coverage
    network_accuracy  — Table II accuracy parity (3 nets × 3 rules)
    accuracy          — unsupervised train-to-accuracy: ITP vs exact
                        STDP end-to-end (homeostasis + label assignment)
                        across backends, itp-vs-exact gap gated in CI
    engine_cost       — Tables III-V op/bit model + measured SOP/s
    rule_cost         — per-rule engine throughput, reference + fused
                        (ITP vs the fused counter kernels & co.)
    conv_cost         — im2col-fused conv update: reference vs Pallas grid
    sparse_cost       — event-driven sparse backend: speedup vs spike
                        density + sparse/dense crossover
    serve_cost        — online-plasticity serving: step latency,
                        throughput vs batch, bytes/session + sessions/GiB
                        of the packed-word plasticity cache, interleaved
                        bit-identity (gated in CI)
    roofline          — §Roofline terms from the dry-run artifacts
    static_audit      — jaxpr contract audit fingerprint: per-cell
                        primitive counts of the traced rule × backend ×
                        layer-kind matrix (no execution; CI diffs it)

``--only <name>`` runs a single module; ``--quick`` shrinks the
protocols for CI-speed runs; ``--list`` prints the registered module
names (one per line) and exits.  ``summary.json`` is merged
read-modify-write, so successive ``--only`` invocations accumulate their
metrics instead of clobbering each other.
"""
from __future__ import annotations

import argparse
import json
import os
import time


def _run_drift(args):
    from benchmarks import drift
    r = drift.run(args.out)
    return {"rmse": r["metrics"]["update_curve_rmse"]}


def _run_isi(args):
    from benchmarks import isi
    r = isi.run(args.out)
    return {"coverage_at_7": r["pooled_coverage_at_7"]}


def _run_network_accuracy(args):
    from benchmarks import network_accuracy
    kw = {"n_train": 48, "n_test": 32, "seeds": (0,)} if args.quick else {}
    network_accuracy.run(args.out, **kw)
    return {}


def _run_accuracy(args):
    from benchmarks import accuracy
    r = accuracy.run(args.out, quick=args.quick)
    return {"itp_vs_exact_gap": r["itp_vs_exact_gap"],
            "finals": {f"{c['rule']}/{c['backend']}": c["final_accuracy"]
                       for c in r["cells"]}}


def _run_engine_cost(args):
    from benchmarks import engine_cost
    if args.quick:
        r = engine_cost.run(args.out, sizes=(64, 256),
                            grid_sizes=(64, 128, 256), grid_batches=(1, 4),
                            grid_steps=25, quick=True)
    else:
        r = engine_cost.run(args.out)
    return {"speedups": [t["speedup"] for t in r["throughput"]],
            "fused_speedups": [c["fused_speedup"] for c in r["backend_grid"]]}


def _run_rule_cost(args):
    from benchmarks import rule_cost
    if args.quick:
        r = rule_cost.run(args.out, sizes=(64, 128), t_steps=25, quick=True)
    else:
        r = rule_cost.run(args.out)
    return {"itp_vs_exact": [c.get("itp_vs_exact_speedup")
                             for c in r["grid"]],
            "fused_itp_vs_exact": [c.get("fused_itp_vs_exact_speedup")
                                   for c in r["grid"]]}


def _run_conv_cost(args):
    from benchmarks import conv_cost
    r = conv_cost.run(args.out, quick=args.quick)
    return {"fused_speedups": [c["fused_speedup"] for c in r["grid"]]}


def _run_sparse_cost(args):
    from benchmarks import sparse_cost
    if args.quick:
        r = sparse_cost.run(args.out, n=64, t_steps=25,
                            densities=sparse_cost.QUICK_DENSITIES, quick=True)
    else:
        r = sparse_cost.run(args.out)
    return {"model_speedups": [c["model_speedup"] for c in r["grid"]],
            "measured_speedups": [c["measured_speedup"] for c in r["grid"]],
            "crossover_density_model": r["crossover_density_model"]}


def _run_serve_cost(args):
    from benchmarks import serve_cost
    if args.quick:
        r = serve_cost.run(args.out, n_pre=32, n_post=16, t_steps=8,
                           max_batch=4, reps=5,
                           batch_sizes=serve_cost.QUICK_BATCH_SIZES,
                           quick=True)
    else:
        r = serve_cost.run(args.out)
    return {"p50_ms": r["latency"]["p50_ms"],
            "p99_ms": r["latency"]["p99_ms"],
            "bytes_per_neuron": {m["rule"]: m["bytes_per_neuron"]
                                 for m in r["memory"]},
            "interleaved_bit_identical":
                r["isolation"]["interleaved_bit_identical"]}


def _run_roofline(args):
    from benchmarks import roofline
    r = roofline.run(args.out)
    return {"cells": len(r["rows"]), "missing": len(r["missing"])}


def _run_static_audit(args):
    from benchmarks import static_audit
    r = static_audit.run(args.out, quick=args.quick)
    return {"n_cells": r["n_cells"], "n_violating": r["n_violating"]}


# name → runner; insertion order is execution order.  --only choices,
# --list, and the dispatch loop below all read THIS dict — add a module
# here and every CLI surface picks it up.
MODULES = {
    "drift": _run_drift,
    "isi": _run_isi,
    "network_accuracy": _run_network_accuracy,
    "accuracy": _run_accuracy,
    "engine_cost": _run_engine_cost,
    "rule_cost": _run_rule_cost,
    "conv_cost": _run_conv_cost,
    "sparse_cost": _run_sparse_cost,
    "serve_cost": _run_serve_cost,
    "roofline": _run_roofline,
    "static_audit": _run_static_audit,
}


def _merge_summary(path: str, update: dict) -> dict:
    """Read-modify-write summary.json so --only runs accumulate."""
    summary = {}
    if os.path.exists(path):
        try:
            with open(path) as f:
                summary = json.load(f)
        except (json.JSONDecodeError, OSError):
            summary = {}
    summary.update(update)
    with open(path, "w") as f:
        json.dump(summary, f, indent=1)
    return summary


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", choices=tuple(MODULES))
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--list", action="store_true",
                    help="print the registered benchmark modules and exit")
    ap.add_argument("--out", default="experiments/bench")
    args = ap.parse_args()

    if args.list:
        for name in MODULES:
            print(name)
        return

    os.makedirs(args.out, exist_ok=True)
    results = {}
    t_start = time.time()
    for name, runner in MODULES.items():
        if args.only is not None and args.only != name:
            continue
        t0 = time.time()
        metrics = runner(args)
        results[name] = {"seconds": round(time.time() - t0, 1), **metrics}
        print()

    results["total_seconds"] = round(time.time() - t_start, 1)
    _merge_summary(os.path.join(args.out, "summary.json"), results)
    print(f"benchmarks complete in {results['total_seconds']}s "
          f"→ {args.out}/")


if __name__ == "__main__":
    main()
