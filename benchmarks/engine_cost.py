"""Benchmark: Tables III-V analogue — per-update operation/storage model +
measured learning-engine throughput.

Silicon metrics (GHz, µm², pJ/SOP) do not transfer to a JAX repro
(DESIGN.md §2); what does transfer is the *operation-count asymmetry* the
tables monetise.  Two parts:

1. **Op/bit-count model** — arithmetic ops + storage bits per synaptic
   weight update for each STDP implementation family.  Reproduces the
   paper's structural claim: ITP-STDP needs no exponential, no multiplier,
   no LUT — only register reads, shifts, adds.

2. **Measured throughput (SOP/s)** — the ITP engine vs the conventional
   counter-based exact-STDP engine (identical LIF dynamics, identical
   pairing) at several sizes, both jit-compiled.  CPU wall-time stands in
   for the hardware's cycle count; the *ratio* is the algorithmic win.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.bench_io import update_bench_json
from repro.core.engine import (EngineConfig, init_engine,
                               init_engine_population, run_engine,
                               run_engine_population)
from repro.kernels.dispatch import default_fused_backend, resolve_backend

# ---------------------------------------------------------------------------
# 1. Op/bit-count model (per synaptic weight update, nearest-neighbour)
# ---------------------------------------------------------------------------
# Conventions: depth-7 history, 8-bit weights (the paper's datapath).
# 'exp' = base-e exponential evaluation; 'approx_mul' = Mitchell-style
# shift-add multiply (3 per LLSMu); 'lut_bits' = precomputed-table storage.

D = 7          # history depth
WB = 8         # weight bits

OP_MODEL = {
    # counter Δt + exp + A·(.) multiply + accumulate      [26]/[28]-style
    "P-STDP (exact)": {
        "exp": 1, "mul": 1, "approx_mul": 0, "sub": 1, "shift": 0,
        "add": 1, "lut_bits": 0,
        "state_bits_per_neuron": 2 * 8,            # 2 saturating counters
    },
    # PWL approximation [24]: slope multiply + clip
    "P-STDP (linear [24])": {
        "exp": 0, "mul": 1, "approx_mul": 0, "sub": 2, "shift": 0,
        "add": 1, "lut_bits": 0,
        "state_bits_per_neuron": 2 * 8,
    },
    # trace-based with LLSMu approximate multiplier [29]
    "t-STDP (LLMu [29])": {
        "exp": 0, "mul": 0, "approx_mul": 1, "sub": 1, "shift": 2,
        "add": 2, "lut_bits": 0,
        "state_bits_per_neuron": 2 * WB,           # pre/post traces
    },
    # index-difference + precomputed LUT [23]
    "ImSTDP [23]": {
        "exp": 0, "mul": 0, "approx_mul": 0, "sub": 1, "shift": 0,
        "add": 1, "lut_bits": 2 * D * WB,          # LTP+LTD tables
        "state_bits_per_neuron": 2 * 8,            # spike indices
    },
    # this work: register read IS the update
    "ITP-STDP (this work)": {
        "exp": 0, "mul": 0, "approx_mul": 0, "sub": 0, "shift": 1,
        "add": 1, "lut_bits": 0,
        "state_bits_per_neuron": D,                # the shift register
    },
    # reward-modulated ITP (rule="mstdp"): the same register read scaled
    # by a per-neuron eligibility word — shift decay + credit add on the
    # word, one multiply for the /128 fixed-point modulation (reward
    # folds into the same scale)
    "R-STDP (mstdp, this work)": {
        "exp": 0, "mul": 1, "approx_mul": 0, "sub": 0, "shift": 2,
        "add": 2, "lut_bits": 0,
        "state_bits_per_neuron": D + 8,            # registers + eligibility
    },
}


# ---------------------------------------------------------------------------
# 2. Measured throughput
# ---------------------------------------------------------------------------

def _time_fn(fn, *args, reps: int = 3) -> float:
    fn(*args)                       # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def measure_throughput(n: int, t_steps: int = 100, seed: int = 0) -> dict:
    """ITP engine vs the counter-based exact-STDP rule, unified engine API.

    ``rule="exact"`` is the old standalone CounterEngine folded into the
    learning-rule registry (per-pair Δt + base-e exponential); identical
    LIF dynamics and scan loop, so the ratio isolates the update datapath.
    """
    key = jax.random.PRNGKey(seed)
    train = jax.random.bernoulli(key, 0.3, (t_steps, n))

    itp_cfg = EngineConfig(n_pre=n, n_post=n)
    itp_state = init_engine(key, itp_cfg)
    itp = jax.jit(lambda s, x: run_engine(s, x, itp_cfg))
    t_itp = _time_fn(itp, itp_state, train)

    cnt_cfg = EngineConfig(n_pre=n, n_post=n, rule="exact")
    cnt_state = init_engine(key, cnt_cfg)
    cnt = jax.jit(lambda s, x: run_engine(s, x, cnt_cfg))
    t_cnt = _time_fn(cnt, cnt_state, train)

    sops = n * n * t_steps
    return {"n": n, "t_steps": t_steps,
            "itp_sops_per_s": sops / t_itp,
            "counter_sops_per_s": sops / t_cnt,
            "speedup": t_cnt / t_itp}


def measure_backend_throughput(n: int, replicas: int, t_steps: int,
                               backend: str, seed: int = 0) -> float:
    """SOP/s of the population engine on one weight-update backend."""
    key = jax.random.PRNGKey(seed)
    cfg = EngineConfig(n_pre=n, n_post=n, backend=backend)
    states = init_engine_population(key, cfg, replicas)
    trains = jax.random.bernoulli(jax.random.fold_in(key, 1), 0.3,
                                  (replicas, t_steps, n))
    fn = jax.jit(lambda s, x: run_engine_population(s, x, cfg))
    t = _time_fn(fn, states, trains)
    return replicas * t_steps * n * n / t


def fused_backend_name() -> str:
    """The fused backend this host can actually run.

    Delegates to ``repro.kernels.dispatch.default_fused_backend`` (CPU can
    only run the Pallas kernels through the interpreter; on an accelerator
    the real compiled kernel is measured).  The chosen name is recorded in
    the artifact so interpreter numbers are never mistaken for kernel
    numbers.
    """
    return default_fused_backend()


# ---------------------------------------------------------------------------
# 3. Packed vs unpacked history datapath (HBM bytes + throughput)
# ---------------------------------------------------------------------------

def measure_packed_history(n: int, depth: int = 7, t_steps: int = 50,
                           seed: int = 0) -> dict:
    """Packed uint8 words vs unpacked float32 bitplanes into the fused kernel.

    Times a jitted ``t_steps`` scan of the fused weight update fed by (a)
    depth-major ``(depth, n)`` float32 bitplane registers and (b) one packed
    uint8 word per neuron, and records the per-step history bytes each
    variant moves into the kernel — the ~``4·depth``× traffic reduction the
    paper's 8-bit register file realises (ROADMAP bandwidth item).
    """
    from repro.core.history import pack_bitplanes
    from repro.core.stdp import STDPParams
    from repro.kernels.itp_stdp.ops import (weight_update_depth_major,
                                            weight_update_packed)

    _, interpret = resolve_backend(fused_backend_name())
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    pre_bits = jax.random.bernoulli(ks[0], 0.3, (t_steps, depth, n))
    post_bits = jax.random.bernoulli(ks[1], 0.3, (t_steps, depth, n))
    pre_s = jax.random.bernoulli(ks[2], 0.35, (t_steps, n))
    post_s = jax.random.bernoulli(ks[3], 0.35, (t_steps, n))
    # (t, n) uint8 words via the canonical packer (depth axis first)
    pre_words = jax.vmap(pack_bitplanes)(pre_bits)
    post_words = jax.vmap(pack_bitplanes)(post_bits)
    params = STDPParams()
    eta = 1.0 / 16.0

    def scan_unpacked(w):
        def step(w, xs):
            p, q, pb, qb = xs
            return weight_update_depth_major(
                w, p, q, pb, qb, params, eta=eta, interpret=interpret), None
        out, _ = jax.lax.scan(step, w, (pre_s, post_s, pre_bits, post_bits))
        return out

    def scan_packed(w):
        def step(w, xs):
            p, q, pw, qw = xs
            return weight_update_packed(
                w, p, q, pw, qw, params, depth=depth, eta=eta,
                interpret=interpret), None
        out, _ = jax.lax.scan(step, w, (pre_s, post_s, pre_words, post_words))
        return out

    w0 = jnp.full((n, n), 0.5, jnp.float32)
    t_unpacked = _time_fn(jax.jit(scan_unpacked), w0)
    t_packed = _time_fn(jax.jit(scan_packed), w0)
    sops = n * n * t_steps
    return {
        "n": n, "depth": depth, "t_steps": t_steps,
        # per-step history operand bytes entering the kernel (pre + post)
        "unpacked_history_bytes_per_step": 2 * depth * n * 4,
        "packed_history_bytes_per_step": 2 * n * 1,
        "history_bytes_reduction": float(4 * depth),
        "unpacked_sops_per_s": sops / t_unpacked,
        "packed_sops_per_s": sops / t_packed,
        "packed_speedup": t_unpacked / t_packed,
    }


def measure_backend_grid(sizes=(128, 256, 512), batches=(1, 8),
                         t_steps: int = 50) -> list[dict]:
    """Reference-vs-fused throughput over a (batch × engine-size) grid."""
    fused_name = fused_backend_name()
    rows = []
    for n in sizes:
        for r in batches:
            ref = measure_backend_throughput(n, r, t_steps, "reference")
            fused = measure_backend_throughput(n, r, t_steps, fused_name)
            rows.append({"n": n, "replicas": r, "t_steps": t_steps,
                         "fused_backend": fused_name,
                         "reference_sops_per_s": ref,
                         "fused_sops_per_s": fused,
                         "fused_speedup": fused / ref})
    return rows


def run(out_dir: str = "experiments/bench", verbose: bool = True,
        sizes=(256, 512, 1024), grid_sizes=(128, 256, 512),
        grid_batches=(1, 8), grid_steps: int = 50,
        quick: bool = False) -> dict:
    throughput = [measure_throughput(n) for n in sizes]
    backend_grid = measure_backend_grid(grid_sizes, grid_batches, grid_steps)
    packed_grid = [measure_packed_history(n, t_steps=grid_steps)
                   for n in grid_sizes]
    out = {"op_model": OP_MODEL, "throughput": throughput,
           "backend_grid": backend_grid,
           "packed_grid": packed_grid,
           "paper_claims": {
               "fpga_energy_eff_gain": "4.5x-219.8x",
               "asic_speedup": "4.8x-22.01x",
               "asic_area_fraction": "1.2%-3.3%",
           }}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "engine_cost.json"), "w") as f:
        json.dump(out, f)
    # repo-root perf trajectory artifact: reference vs fused engine
    # throughput per (size, batch) cell — the first point every later
    # scaling PR appends to.  --quick runs use a smaller, incomparable
    # grid, so they write a separate (gitignored) file rather than
    # clobbering the tracked trajectory.  Merged, not overwritten: the
    # conv grid (benchmarks/conv_cost.py) shares the same file.
    bench_name = "BENCH_engine.quick.json" if quick else "BENCH_engine.json"
    update_bench_json(bench_name,
                      {"benchmark": "engine_backend_throughput",
                       "unit": "SOP/s",
                       "quick": quick,
                       "fused_backend": fused_backend_name(),
                       "grid": backend_grid,
                       # packed uint8 words vs unpacked f32 bitplanes into
                       # the fused kernel: HBM history bytes + throughput
                       "packed": {
                           "benchmark": "packed_history_datapath",
                           "unit": "SOP/s",
                           "quick": quick,
                           "fused_backend": fused_backend_name(),
                           "grid": packed_grid,
                       }})
    if verbose:
        print("— engine cost model (paper Tables III-V analogue) —")
        hdr = f"  {'variant':24s} {'exp':>4s} {'mul':>4s} {'amul':>5s} " \
              f"{'sub':>4s} {'shift':>6s} {'add':>4s} {'LUTb':>5s} " \
              f"{'state-b/neuron':>15s}"
        print(hdr)
        for name, m in OP_MODEL.items():
            print(f"  {name:24s} {m['exp']:4d} {m['mul']:4d} "
                  f"{m['approx_mul']:5d} {m['sub']:4d} {m['shift']:6d} "
                  f"{m['add']:4d} {m['lut_bits']:5d} "
                  f"{m['state_bits_per_neuron']:15d}")
        print("  measured engine throughput (jit, CPU timing, relative):")
        for t in throughput:
            print(f"    n={t['n']:5d}: ITP {t['itp_sops_per_s']:.3e} SOP/s  "
                  f"counter-exact {t['counter_sops_per_s']:.3e} SOP/s  "
                  f"speedup ×{t['speedup']:.2f}")
        print("  backend grid (reference vs fused Pallas datapath):")
        for row in backend_grid:
            print(f"    n={row['n']:5d} R={row['replicas']:3d}: "
                  f"ref {row['reference_sops_per_s']:.3e} SOP/s  "
                  f"fused {row['fused_sops_per_s']:.3e} SOP/s  "
                  f"×{row['fused_speedup']:.2f}")
        print("  packed history datapath (uint8 words vs f32 bitplanes):")
        for row in packed_grid:
            print(f"    n={row['n']:5d} d={row['depth']}: "
                  f"{row['unpacked_history_bytes_per_step']:7d} B/step → "
                  f"{row['packed_history_bytes_per_step']:5d} B/step "
                  f"(÷{row['history_bytes_reduction']:.0f})  "
                  f"packed {row['packed_sops_per_s']:.3e} SOP/s  "
                  f"×{row['packed_speedup']:.2f}")
        print(f"  → {bench_name} ({len(backend_grid)} grid cells)")
    return out


if __name__ == "__main__":
    run()
