"""Roofline analysis from the dry-run artifacts (§Roofline deliverable).

Reads experiments/dryrun/*.json (written by repro.launch.dryrun) and
derives, per (arch × shape) on the single-pod mesh:

    compute   = HLO_FLOPs_per_device / peak_FLOPs            [s]
    memory    = HLO_bytes_per_device / HBM_bw                [s]
    collective= collective_operand_bytes_per_device / link_bw [s]

plus the dominant term, MODEL_FLOPS = 6·N·D (6·N_active·D for MoE), and
the usefulness ratio MODEL_FLOPS / HLO_FLOPs (catches remat/redundancy).

Hardware constants (TPU v5e, per the brief): 197 TFLOP/s bf16, 819 GB/s
HBM, ~50 GB/s/link ICI.

FLOP/collective counts come from the *unrolled* measurement program when
available (``cost_unrolled``; scanned modules undercount loop bodies) and
otherwise from the layer-calibrated extrapolation (``cost_extrapolated``).
"""
from __future__ import annotations

import json
import os

from repro.configs import ARCH_NAMES, get_config
from repro.configs.shapes import SHAPES

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
LINK_BW = 50e9           # B/s / link
HBM_PER_CHIP = 16e9      # v5e HBM capacity


def model_flops(cfg, shape) -> float:
    """6·N·D (dense) or 6·N_active·D (MoE); decode: D = global_batch
    tokens per step, forward-only (2·N·D)."""
    n = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch          # one token per sequence
    return 2.0 * n * tokens


def load_cell(dry_dir: str, arch: str, shape: str, multi_pod: bool) -> dict | None:
    pod = "multipod" if multi_pod else "singlepod"
    path = os.path.join(dry_dir, f"{arch.replace('.', '_')}__{shape}__{pod}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def analyse_cell(rec: dict) -> dict | None:
    if not rec.get("ok"):
        return None
    arch, shape_name = rec["arch"], rec["shape"]
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    chips = rec["n_devices"]

    source = None
    if rec.get("cost_unrolled"):
        cost, coll, source = (rec["cost_unrolled"],
                              rec.get("collectives_unrolled", {}),
                              "unrolled")
    elif rec.get("cost_extrapolated"):
        cost, coll, source = (rec["cost_extrapolated"],
                              rec.get("collectives_extrapolated", {}),
                              "extrapolated")
    else:
        cost, coll, source = rec.get("cost", {}), rec.get("collectives", {}), \
            "scanned(undercounted)"

    flops_dev = cost.get("flops", 0.0)
    bytes_dev = cost.get("bytes accessed", 0.0)
    coll_dev = coll.get("total_operand_bytes", 0)
    wire_dev = coll.get("total_wire_bytes", 0)

    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll_dev / LINK_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    mf_dev = mf / chips
    t_total = max(terms.values())
    mem = rec.get("memory", {}) or rec.get("memory_unrolled", {})
    hbm_bytes = (mem.get("argument_size_in_bytes", 0)
                 + mem.get("temp_size_in_bytes", 0)
                 + mem.get("output_size_in_bytes", 0))
    return {
        "arch": arch, "shape": shape_name, "chips": chips,
        "source": source,
        "flops_per_dev": flops_dev,
        "bytes_per_dev": bytes_dev,
        "coll_operand_bytes_per_dev": coll_dev,
        "coll_wire_bytes_per_dev": wire_dev,
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_per_dev": mf_dev,
        "useful_ratio": (mf_dev / flops_dev) if flops_dev else 0.0,
        "roofline_fraction": (mf_dev / PEAK_FLOPS) / t_total
        if t_total > 0 else 0.0,
        "hbm_bytes_per_dev": hbm_bytes,
        "fits_hbm": hbm_bytes <= HBM_PER_CHIP if hbm_bytes else None,
    }


def run(out_dir: str = "experiments/bench",
        dry_dir: str = "experiments/dryrun", verbose: bool = True) -> dict:
    rows = []
    missing = []
    for arch in ARCH_NAMES:
        cfg = get_config(arch)
        from repro.configs import shapes_for
        for shape in shapes_for(cfg):
            rec = load_cell(dry_dir, arch, shape.name, multi_pod=False)
            if rec is None:
                missing.append((arch, shape.name))
                continue
            row = analyse_cell(rec)
            if row:
                rows.append(row)
            else:
                missing.append((arch, shape.name))
    out = {"rows": rows, "missing": missing,
           "constants": {"peak_flops": PEAK_FLOPS, "hbm_bw": HBM_BW,
                         "link_bw": LINK_BW}}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "roofline.json"), "w") as f:
        json.dump(out, f)
    if verbose:
        print("— roofline (single-pod 16×16, per device) —")
        print(f"  {'arch':22s} {'shape':12s} {'comp[s]':>9s} {'mem[s]':>9s} "
              f"{'coll[s]':>9s} {'dom':>5s} {'useful':>7s} {'roof%':>6s} "
              f"{'src':>14s}")
        for r in rows:
            print(f"  {r['arch']:22s} {r['shape']:12s} "
                  f"{r['t_compute_s']:9.2e} {r['t_memory_s']:9.2e} "
                  f"{r['t_collective_s']:9.2e} {r['dominant'][:4]:>5s} "
                  f"{r['useful_ratio']:7.2f} "
                  f"{100 * r['roofline_fraction']:6.1f} {r['source']:>14s}")
        if missing:
            print(f"  missing cells: {len(missing)} (dry-run incomplete)")
    return out


if __name__ == "__main__":
    run()
