"""Benchmark: learning-rule cost — engine-step throughput per rule.

The repo-side analogue of the paper's speedup tables on the *rule* axis:
every rule in the ``repro.plasticity`` registry drives the same engine
(identical LIF dynamics, scan loop, and jit) over a small size grid, so
the throughput ratio isolates the weight-update datapath — the
intrinsic-timing register read (``itp``) against the conventional
per-pair Δt datapaths (``exact``/``linear``/``imstdp``).  CPU wall-time
stands in for the hardware's cycle count; the *ratio* is the algorithmic
claim.

Two backend columns per rule close the paper's actual comparison:

  * ``reference``  — the pure-jnp datapaths (algorithmic ratio);
  * the host's fused backend (``repro.kernels.dispatch.
    default_fused_backend``: compiled Pallas on accelerators, the
    interpreter on CPU) — **kernel-vs-kernel**, fused ITP against the
    fused counter kernels of ``repro.kernels.itp_counter``, which is the
    Tables III-V measurement basis.

Each cell also carries ``model_cost_per_update`` — the per-synaptic-
update datapath cost from ``engine_cost.OP_MODEL`` under the explicit
``OP_WEIGHTS`` below.  This is the host-independent form of the paper's
ordering (ITP's shift+add read is cheaper than every per-pair window
datapath) and is what CI gates unconditionally; the measured fused
wall-clock ordering is gated only where it is meaningful — on a
compiled fused backend — because the CPU interpreter prices every
kernel by its memory traffic, not its datapath (same caveat as the
conv/packed grids, see ROADMAP).

Headline cell: ``itp`` vs ``exact`` — the ITP-STDP engine against the
counter-based exact-STDP baseline it replaces (identical trajectories
under nearest-neighbour pairing, eq. 18).

Merges a ``rules`` section into the tracked repo-root BENCH_engine.json
(``benchmarks/bench_io.py`` read-modify-write, never clobbering the
engine/conv sections); ``--quick`` runs use the smaller, incomparable
grid and land in the gitignored ``.quick`` twin.
"""

from __future__ import annotations

import json
import os
import time

import jax

from benchmarks.bench_io import update_bench_json
from repro.core.engine import EngineConfig, init_engine, run_engine
from repro.kernels.dispatch import default_fused_backend
from repro.plasticity import rule_names

HEADLINE = ("itp", "exact")

# Relative datapath cost per op class (hardware-flavoured: a base-e
# exponential unit against shift/add primitives).  Only the *ordering* is
# load-bearing — the CI regression gate asserts ITP's modelled cost stays
# below every counter rule's, the structural claim of Tables III-V.
OP_WEIGHTS = {"exp": 32.0, "mul": 8.0, "approx_mul": 3.0, "sub": 1.0, "shift": 0.5, "add": 1.0}

# registry rule → engine_cost.OP_MODEL row (the per-update op counts)
RULE_TO_MODEL = {
    "itp": "ITP-STDP (this work)",
    "itp_nocomp": "ITP-STDP (this work)",
    "exact": "P-STDP (exact)",
    "linear": "P-STDP (linear [24])",
    "imstdp": "ImSTDP [23]",
    "mstdp": "R-STDP (mstdp, this work)",
}


def modelled_update_cost(rule: str) -> float | None:
    """Weighted per-synaptic-update datapath op cost of ``rule``'s kernel."""
    from benchmarks.engine_cost import OP_MODEL

    row = OP_MODEL.get(RULE_TO_MODEL.get(rule, ""))
    if row is None:
        return None
    return sum(row[op] * weight for op, weight in OP_WEIGHTS.items())


def _time_fn(fn, *args, reps: int = 3) -> float:
    fn(*args)  # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def measure_rule_throughput(
    rule: str, n: int, t_steps: int, seed: int = 0, backend: str = "reference"
) -> float:
    """SOP/s of a jitted engine scan under ``rule`` on ``backend``."""
    key = jax.random.PRNGKey(seed)
    cfg = EngineConfig(n_pre=n, n_post=n, rule=rule, backend=backend)
    state = init_engine(key, cfg)
    train = jax.random.bernoulli(key, 0.3, (t_steps, n))
    fn = jax.jit(lambda s, x: run_engine(s, x, cfg))
    return n * n * t_steps / _time_fn(fn, state, train)


def measure_rule_grid(sizes=(128, 256, 512), t_steps: int = 50, rules=None) -> list[dict]:
    """Per-rule engine throughput over a size grid, reference AND fused.

    Each cell carries ``sops_per_s`` (reference backend, the algorithmic
    ratio) and ``fused_sops_per_s`` (the host's fused backend — the
    kernel-vs-kernel Tables III-V basis) for every rule, plus the
    headline itp/exact speedups on both columns.
    """
    rules = tuple(rules) if rules is not None else rule_names()
    fused = default_fused_backend()
    rows = []
    for n in sizes:
        cell = {
            "n": n,
            "t_steps": t_steps,
            "fused_backend": fused,
            "sops_per_s": {},
            "fused_sops_per_s": {},
            "model_cost_per_update": {r: modelled_update_cost(r) for r in rules},
        }
        for rule in rules:
            cell["sops_per_s"][rule] = measure_rule_throughput(rule, n, t_steps)
            cell["fused_sops_per_s"][rule] = measure_rule_throughput(
                rule, n, t_steps, backend=fused
            )
        itp, exact = (cell["sops_per_s"].get(r) for r in HEADLINE)
        if itp and exact:
            cell["itp_vs_exact_speedup"] = itp / exact
        f_itp, f_exact = (cell["fused_sops_per_s"].get(r) for r in HEADLINE)
        if f_itp and f_exact:
            cell["fused_itp_vs_exact_speedup"] = f_itp / f_exact
        rows.append(cell)
    return rows


def run(
    out_dir: str = "experiments/bench",
    verbose: bool = True,
    sizes=(128, 256, 512),
    t_steps: int = 50,
    quick: bool = False,
) -> dict:
    grid = measure_rule_grid(sizes, t_steps)
    out = {
        "grid": grid,
        "rules": list(rule_names()),
        "fused_backend": default_fused_backend(),
        "quick": quick,
        "note": "reference + fused backends; ratios isolate the update datapath",
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "rule_cost.json"), "w") as f:
        json.dump(out, f)
    bench_name = "BENCH_engine.quick.json" if quick else "BENCH_engine.json"
    update_bench_json(
        bench_name,
        {
            "rules": {
                "benchmark": "rule_throughput",
                "unit": "SOP/s",
                "quick": quick,
                "fused_backend": out["fused_backend"],
                "grid": grid,
            }
        },
    )
    if verbose:
        names = list(rule_names())
        for col, title in (
            ("sops_per_s", "reference"),
            ("fused_sops_per_s", f"fused ({out['fused_backend']})"),
        ):
            print(f"— learning-rule cost, {title} backend —")
            hdr = "  " + f"{'n':>6s} " + " ".join(f"{r:>12s}" for r in names)
            hdr += f" {'itp/exact':>10s}"
            print(hdr)
            key = "itp_vs_exact_speedup" if col == "sops_per_s" else "fused_itp_vs_exact_speedup"
            for cell in grid:
                vals = " ".join(f"{cell[col][r]:12.3e}" for r in names)
                spd = cell.get(key, float("nan"))
                print(f"  {cell['n']:6d} {vals} {spd:10.2f}")
        print(f"  → {bench_name} (rules section, {len(grid)} grid cells)")
    return out


if __name__ == "__main__":
    run()
