"""Benchmark: learning-rule cost — engine-step throughput per rule.

The repo-side analogue of the paper's speedup tables on the *rule* axis:
every rule in the ``repro.plasticity`` registry drives the same engine
(identical LIF dynamics, scan loop, and jit) over a small size grid, so
the throughput ratio isolates the weight-update datapath — the
intrinsic-timing register read (``itp``) against the conventional
per-pair Δt datapaths (``exact``/``linear``/``imstdp``).  CPU wall-time
stands in for the hardware's cycle count; the *ratio* is the algorithmic
claim.

Headline cell: ``itp`` vs ``exact`` — the ITP-STDP engine against the
counter-based exact-STDP baseline it replaces (identical trajectories
under nearest-neighbour pairing, eq. 18).

Merges a ``rules`` section into the tracked repo-root BENCH_engine.json
(``benchmarks/bench_io.py`` read-modify-write, never clobbering the
engine/conv sections); ``--quick`` runs use the smaller, incomparable
grid and land in the gitignored ``.quick`` twin.
"""

from __future__ import annotations

import json
import os
import time

import jax

from benchmarks.bench_io import update_bench_json
from repro.core.engine import EngineConfig, init_engine, run_engine
from repro.plasticity import rule_names

HEADLINE = ("itp", "exact")


def _time_fn(fn, *args, reps: int = 3) -> float:
    fn(*args)  # compile + warm
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best


def measure_rule_throughput(rule: str, n: int, t_steps: int, seed: int = 0) -> float:
    """SOP/s of a jitted engine scan under ``rule`` (reference backend)."""
    key = jax.random.PRNGKey(seed)
    cfg = EngineConfig(n_pre=n, n_post=n, rule=rule)
    state = init_engine(key, cfg)
    train = jax.random.bernoulli(key, 0.3, (t_steps, n))
    fn = jax.jit(lambda s, x: run_engine(s, x, cfg))
    return n * n * t_steps / _time_fn(fn, state, train)


def measure_rule_grid(sizes=(128, 256, 512), t_steps: int = 50, rules=None) -> list[dict]:
    """Per-rule engine throughput over a size grid (reference backend)."""
    rules = tuple(rules) if rules is not None else rule_names()
    rows = []
    for n in sizes:
        cell = {"n": n, "t_steps": t_steps, "sops_per_s": {}}
        for rule in rules:
            cell["sops_per_s"][rule] = measure_rule_throughput(rule, n, t_steps)
        itp, exact = (cell["sops_per_s"].get(r) for r in HEADLINE)
        if itp and exact:
            cell["itp_vs_exact_speedup"] = itp / exact
        rows.append(cell)
    return rows


def run(
    out_dir: str = "experiments/bench",
    verbose: bool = True,
    sizes=(128, 256, 512),
    t_steps: int = 50,
    quick: bool = False,
) -> dict:
    grid = measure_rule_grid(sizes, t_steps)
    out = {
        "grid": grid,
        "rules": list(rule_names()),
        "quick": quick,
        "note": "reference backend; ratio isolates the update datapath",
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "rule_cost.json"), "w") as f:
        json.dump(out, f)
    bench_name = "BENCH_engine.quick.json" if quick else "BENCH_engine.json"
    update_bench_json(
        bench_name,
        {
            "rules": {
                "benchmark": "rule_throughput",
                "unit": "SOP/s",
                "quick": quick,
                "grid": grid,
            }
        },
    )
    if verbose:
        print("— learning-rule cost (engine-step throughput per rule) —")
        names = list(rule_names())
        hdr = "  " + f"{'n':>6s} " + " ".join(f"{r:>12s}" for r in names)
        hdr += f" {'itp/exact':>10s}"
        print(hdr)
        for cell in grid:
            vals = " ".join(f"{cell['sops_per_s'][r]:12.3e}" for r in names)
            spd = cell.get("itp_vs_exact_speedup", float("nan"))
            print(f"  {cell['n']:6d} {vals} {spd:10.2f}")
        print(f"  → {bench_name} (rules section, {len(grid)} grid cells)")
    return out


if __name__ == "__main__":
    run()
