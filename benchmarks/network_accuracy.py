"""Benchmark: Table II — accuracy parity of STDP variants across the
paper's three networks.

Protocol (identical across rules, so differences isolate the rule):
unsupervised STDP feature learning → frozen features → ridge readout.
Datasets are the synthetic stand-ins (MNIST & co. are not available
offline — DESIGN.md §8); the claim under test is *parity* between
original STDP, ITP-STDP (comp.) and ITP-STDP (w/o comp.), which the paper
reports as ≤ ~0.4 pp spread on MNIST and no systematic degradation."""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from repro.data import (encode_batch, synthetic_digits, synthetic_fashion,
                        synthetic_fault)
from repro.models import snn

PAPER_TABLE_II = {
    "2layer-snn": {"exact": 94.28, "itp": 94.26, "itp_nocomp": 94.13},
    "6layer-dcsnn": {"exact": 86.85, "itp": 91.25, "itp_nocomp": 91.10},
    "5layer-csnn": {"exact": 88.10, "itp": 98.15, "itp_nocomp": 97.76},
}

NETWORKS = {
    "2layer-snn": (snn.mnist_2layer,
                   lambda k, n: synthetic_digits(k, n), 10),
    "6layer-dcsnn": (snn.fmnist_dcsnn,
                     lambda k, n: synthetic_fashion(k, n), 10),
    "5layer-csnn": (snn.fault_csnn,
                    lambda k, n: synthetic_fault(k, n, length=512), 4),
}

RULES = ("exact", "itp", "itp_nocomp")


def eval_network(cfg, sampler, n_classes, *, n_train=96, n_test=64,
                 T=30, B=16, seed=0) -> float:
    key = jax.random.PRNGKey(seed)
    st = snn.init_snn(key, cfg, B)
    k = key
    for _ in range(n_train // B):
        k, kd, ke = jax.random.split(k, 3)
        x, _ = sampler(kd, B)
        st, _ = snn.run_snn(st, encode_batch(ke, x, T), cfg, train=True)
        st = snn.reset_dynamics(st, cfg, B)

    def feats(n, seed2):
        fs, ls = [], []
        kk = jax.random.PRNGKey(seed2)
        s = st
        for _ in range(n // B):
            kk, kd, ke = jax.random.split(kk, 3)
            x, y = sampler(kd, B)
            s = snn.reset_dynamics(s, cfg, B)
            s, c = snn.run_snn(s, encode_batch(ke, x, T), cfg, train=False)
            fs.append(c)
            ls.append(y)
        return jnp.concatenate(fs), jnp.concatenate(ls)

    Xtr, ytr = feats(n_train, 1000 + seed)
    Xte, yte = feats(n_test, 2000 + seed)
    W = snn.fit_readout(Xtr, ytr, n_classes)
    return snn.readout_accuracy(W, Xte, yte)


def run(out_dir: str = "experiments/bench", verbose: bool = True,
        n_train: int = 96, n_test: int = 64, seeds=(0, 1)) -> dict:
    results: dict = {}
    for net, (maker, sampler, n_classes) in NETWORKS.items():
        results[net] = {}
        for rule in RULES:
            accs = []
            for seed in seeds:
                cfg = maker(rule)
                t0 = time.time()
                acc = eval_network(cfg, sampler, n_classes,
                                   n_train=n_train, n_test=n_test,
                                   seed=seed)
                accs.append(acc)
            results[net][rule] = {
                "mean": float(sum(accs) / len(accs)),
                "accs": [float(a) for a in accs],
            }
        vals = [results[net][r]["mean"] for r in RULES]
        results[net]["parity_spread"] = float(max(vals) - min(vals))
        results[net]["chance"] = 1.0 / n_classes

    out = {"results": results, "paper_table_ii": PAPER_TABLE_II,
           "protocol": {"n_train": n_train, "n_test": n_test,
                        "t_steps": 30, "seeds": list(seeds)}}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "network_accuracy.json"), "w") as f:
        json.dump(out, f)
    if verbose:
        print("— network accuracy parity (paper Table II) —")
        print(f"  {'network':14s} {'exact':>8s} {'itp':>8s} "
              f"{'nocomp':>8s} {'spread':>8s} {'chance':>7s}")
        for net in NETWORKS:
            r = results[net]
            print(f"  {net:14s} "
                  f"{r['exact']['mean']:8.3f} {r['itp']['mean']:8.3f} "
                  f"{r['itp_nocomp']['mean']:8.3f} "
                  f"{r['parity_spread']:8.3f} {r['chance']:7.2f}")
        print("  (synthetic stand-in data: the tested claim is parity "
              "between rules, not absolute accuracy)")
    return out


if __name__ == "__main__":
    run()
