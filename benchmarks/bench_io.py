"""Shared read-modify-write helper for the tracked BENCH_*.json artifacts.

Multiple benchmark modules contribute sections to the same repo-root
trajectory file (the dense engine grid and the conv grid both land in
BENCH_engine.json); each merges only its own top-level keys and leaves the
siblings in place, so ``--only`` runs never clobber another module's
numbers.
"""

from __future__ import annotations

import json
import os

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def update_bench_json(name: str, updates: dict) -> str:
    """Merge ``updates`` into the repo-root file ``name``; returns the path.

    The write is atomic (temp file + rename) so a killed run can never
    leave a truncated trajectory behind; an unreadable pre-existing file
    still fails loudly rather than being silently reset, since it holds
    the sibling modules' sections.
    """
    path = os.path.join(REPO_ROOT, name)
    data: dict = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data.update(updates)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(data, f, indent=1)
    os.replace(tmp, path)
    return path
