"""Shared read-modify-write helper for the tracked BENCH_*.json artifacts.

Multiple benchmark modules contribute sections to the same repo-root
trajectory file (the dense engine grid and the conv grid both land in
BENCH_engine.json); each merges only its own top-level keys and leaves the
siblings in place, so ``--only`` runs never clobber another module's
numbers.
"""

from __future__ import annotations

import json
import os
import tempfile

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

# the scratch file lives in the same directory as the target (os.replace
# must not cross filesystems) but under a gitignored name (`.bench-*.tmp`,
# see .gitignore): a run killed between write and rename never leaves an
# untracked stray that matches a tracked BENCH_* pattern in the repo root
_TMP_PREFIX = ".bench-"
_TMP_SUFFIX = ".tmp"


def update_bench_json(name: str, updates: dict) -> str:
    """Merge ``updates`` into the repo-root file ``name``; returns the path.

    The write is atomic (gitignored temp file + rename) so a killed run can
    never leave a truncated trajectory — or a stray tracked-pattern file —
    behind; an unreadable pre-existing file still fails loudly rather than
    being silently reset, since it holds the sibling modules' sections.
    """
    path = os.path.join(REPO_ROOT, name)
    data: dict = {}
    if os.path.exists(path):
        with open(path) as f:
            data = json.load(f)
    data.update(updates)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path), prefix=_TMP_PREFIX, suffix=_TMP_SUFFIX)
    try:
        # mkstemp creates 0600 scratch files; os.replace would propagate
        # that onto the tracked artifact, so restore the normal
        # umask-derived mode (or the target's existing one) first
        umask = os.umask(0)
        os.umask(umask)
        mode = os.stat(path).st_mode & 0o777 if os.path.exists(path) else 0o666 & ~umask
        os.fchmod(fd, mode)
        with os.fdopen(fd, "w") as f:
            json.dump(data, f, indent=1)
        os.replace(tmp, path)
    except BaseException:
        # best-effort cleanup on any interrupt (KeyboardInterrupt included);
        # even if this unlink loses the race, the name is gitignored
        try:
            os.unlink(tmp)
        except OSError:
            pass
        raise
    return path
