"""Benchmark: reference vs fused im2col conv ITP-STDP update throughput.

The conv layers are where the FLOP bulk of the paper's two conv networks
(6-layer DCSNN, 5-layer CSNN) lives.  This grid times the patch-level
weight update — the pure-jnp reference against the fused Pallas kernel
(interpret mode on CPU, the compiled kernel on an accelerator) — on the
exact conv-layer shapes of those networks, and appends the result to the
tracked BENCH_engine.json trajectory next to the dense engine grid.
"""

from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.bench_io import update_bench_json
from benchmarks.engine_cost import fused_backend_name
from repro.core.history import pack_bitplanes
from repro.core.stdp import STDPParams
from repro.kernels.itp_stdp.ops import resolve_backend
from repro.kernels.itp_stdp_conv.ops import conv_synapse_delta, conv_synapse_delta_packed

DEPTH = 7

# (name, patch rows per sample, patch width K, out channels C): the conv
# layer shapes of the paper's DCSNN (28x28 images) and CSNN (512-sample
# series) stacks; M = batch x rows is the contracted axis.
LAYER_SHAPES = (
    ("dcsnn-conv1", 576, 25, 12),
    ("dcsnn-conv2", 100, 108, 24),
    ("csnn-conv1", 253, 14, 8),
    ("csnn-conv2", 61, 40, 16),
)


def measure_conv_update(
    m: int,
    kk: int,
    cc: int,
    backend: str,
    t_steps: int,
    seed: int = 0,
    packed: bool = False,
) -> float:
    """Best wall-clock of a jitted t_steps scan of the conv weight update.

    ``packed=True`` feeds the fused kernel one uint8 history word per patch
    element (``conv_synapse_delta_packed``) instead of the ``(depth, M, ·)``
    float32 bitplane patches — the storage-format axis of the grid.
    """
    use_kernel, interpret = resolve_backend(backend)
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    pre = jax.random.bernoulli(ks[0], 0.3, (t_steps, m, kk))
    post = jax.random.bernoulli(ks[1], 0.2, (t_steps, m, cc))
    pre_bits = jax.random.bernoulli(ks[2], 0.3, (t_steps, DEPTH, m, kk))
    post_bits = jax.random.bernoulli(ks[3], 0.2, (t_steps, DEPTH, m, cc))
    params = STDPParams()

    if packed:
        # (t, m, ·) uint8 words via the canonical packer (depth axis first)
        pre_words = jax.vmap(pack_bitplanes)(pre_bits)
        post_words = jax.vmap(pack_bitplanes)(post_bits)

        def step(w, xs):
            p, q, pw, qw = xs
            dw = conv_synapse_delta_packed(
                p, q, pw, qw, params, depth=DEPTH, use_kernel=use_kernel, interpret=interpret
            )
            return jnp.clip(w + dw / float(m), 0.0, 1.0), None

        operands = (pre, post, pre_words, post_words)
    else:

        def step(w, xs):
            p, q, pb, qb = xs
            dw = conv_synapse_delta(
                p, q, pb, qb, params, use_kernel=use_kernel, interpret=interpret
            )
            return jnp.clip(w + dw / float(m), 0.0, 1.0), None

        operands = (pre, post, pre_bits, post_bits)

    @jax.jit
    def run_scan(w):
        out, _ = jax.lax.scan(step, w, operands)
        return out

    w0 = jnp.full((kk, cc), 0.5, jnp.float32)
    jax.block_until_ready(run_scan(w0))  # compile + warm
    best = float("inf")
    for _ in range(3):
        t0 = time.perf_counter()
        jax.block_until_ready(run_scan(w0))
        best = min(best, time.perf_counter() - t0)
    return best


def run(out_dir: str = "experiments/bench", verbose: bool = True, quick: bool = False) -> dict:
    t_steps, batch = (8, 2) if quick else (25, 8)
    fused_name = fused_backend_name()
    rows = []
    for name, m, kk, cc in LAYER_SHAPES:
        rows_m = m * batch
        ref_s = measure_conv_update(rows_m, kk, cc, "reference", t_steps)
        fused_s = measure_conv_update(rows_m, kk, cc, fused_name, t_steps)
        packed_s = measure_conv_update(rows_m, kk, cc, fused_name, t_steps, packed=True)
        sops = rows_m * kk * cc * t_steps
        rows.append(
            {
                "layer": name,
                "patch_rows": rows_m,
                "patch_width": kk,
                "out_channels": cc,
                "t_steps": t_steps,
                "fused_backend": fused_name,
                "reference_sops_per_s": sops / ref_s,
                "fused_sops_per_s": sops / fused_s,
                "fused_speedup": ref_s / fused_s,
                # packed uint8 history words vs unpacked f32 bitplane
                # patches into the same fused kernel (per-step bytes are
                # the pre+post history operands)
                "packed_sops_per_s": sops / packed_s,
                "packed_vs_unpacked_speedup": fused_s / packed_s,
                "unpacked_history_bytes_per_step": DEPTH * (rows_m * kk + rows_m * cc) * 4,
                "packed_history_bytes_per_step": (rows_m * kk + rows_m * cc) * 1,
            }
        )

    out = {"grid": rows, "quick": quick}
    os.makedirs(out_dir, exist_ok=True)
    with open(os.path.join(out_dir, "conv_cost.json"), "w") as f:
        json.dump(out, f)
    # merge into the tracked engine trajectory file (quick runs use the
    # smaller, incomparable grid and land in the gitignored .quick twin)
    bench_name = "BENCH_engine.quick.json" if quick else "BENCH_engine.json"
    update_bench_json(
        bench_name,
        {
            "conv": {
                "benchmark": "conv_backend_throughput",
                "unit": "SOP/s",
                "quick": quick,
                "fused_backend": fused_name,
                "grid": rows,
            }
        },
    )
    if verbose:
        print("— conv update cost (im2col-fused ITP-STDP kernel) —")
        for r in rows:
            print(
                f"  {r['layer']:12s} M={r['patch_rows']:5d} "
                f"K={r['patch_width']:4d} C={r['out_channels']:3d}: "
                f"ref {r['reference_sops_per_s']:.3e} SOP/s  "
                f"fused {r['fused_sops_per_s']:.3e} SOP/s  "
                f"x{r['fused_speedup']:.2f}  "
                f"packed {r['packed_sops_per_s']:.3e} SOP/s "
                f"({r['unpacked_history_bytes_per_step']} → "
                f"{r['packed_history_bytes_per_step']} hist B/step)"
            )
        print(f"  → {bench_name} (conv section, {len(rows)} grid cells)")
    return out


if __name__ == "__main__":
    run()
